//! Analytic cost models: sim-grade cycle bills without simulating
//! (ISSUE 6 tentpole).
//!
//! ## The accelerated program's affine cycle law
//!
//! On the block-compiled engine every cycle of a generated program is
//! either **static** (fixed at translation time: fetch transactions,
//! 32-cycle serial ALU passes, load/store latencies, immediate shift
//! amounts — see [`crate::soc::block`]) or **dynamic** from a short,
//! enumerable list: taken branches, register-count shifts, CFU
//! handshakes.  The accelerated inference program
//! ([`crate::program::accel`]) has *no* register-count shifts and a
//! CFU stream whose length depends only on the model shape, so its
//! entire data dependence sits in two branch sites:
//!
//!  * the OvO **vote detour** — a classifier with a negative score
//!    takes the `lw`+`j` side of the sign test instead of the taken
//!    `beq`: one extra instruction, minus the `branch_taken_extra`
//!    cycles (OvR programs have no such branch at all);
//!  * the **argmax update** — each strict running-max improvement in
//!    the OvO vote argmax executes `mv`+`mv` instead of `j`: one extra
//!    instruction, plus a taken `blt`.
//!
//! Everything else is one shared constant.  So the exact bill is
//! affine: `cost(x) = base + n_neg(x)·Dv + n_upd(x)·Du`, with `n_neg`
//! and `n_upd` computable natively from [`crate::svm::infer`] scores.
//! [`AnalyticModel::derive`] measures `base` from one probe inference
//! on the real block-compiled SoC, then **validates the whole law
//! bit-exactly** (full `CycleStats` and the prediction) on a probe
//! battery; any divergence disqualifies the model and the caller
//! (the farm) keeps that config on full simulation.
//!
//! ## The baseline static estimate
//!
//! [`baseline_estimate`] prices the software-only program
//! ([`crate::program::baseline`]) by the same static/dynamic split,
//! but fully closed-form — per shift-add `mul32` call the iteration
//! count is the multiplier's bit length and the add count its
//! popcount, both model constants.  It exists to seed
//! accel-vs-baseline speedup ratios *before* the slow calibration
//! simulation lands (the baseline program is exactly the thing too
//! expensive to simulate eagerly), and is pinned within 10 % of the
//! simulator by tests.

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::serv::{CycleStats, TimingConfig};
use crate::soc::cost::CostVec;
use crate::svm::infer;
use crate::svm::model::{QuantModel, Strategy};
use crate::util::Pcg32;

use super::run::{CompiledProgram, ProgramRunner};
use super::ProgramKind;

/// Per-negative-score delta of the accelerated OvO vote code: the
/// not-taken sign test falls through `lw`+`j` (11 instructions)
/// instead of the taken `beq` path (10), trading one
/// `branch_taken_extra` for one extra fetched+executed instruction.
fn vote_detour(t: &TimingConfig) -> CostVec {
    CostVec {
        fetch: t.fetch_cost() as i64,
        exec: 32 - t.branch_taken_extra as i64,
        instret: 1,
        ..Default::default()
    }
}

/// Per-strict-improvement delta of the vote argmax: the update arm
/// runs `mv`+`mv` after a taken `blt` where the no-update arm jumps
/// away — one extra instruction plus the taken-branch cycles.
fn argmax_update(t: &TimingConfig) -> CostVec {
    CostVec {
        fetch: t.fetch_cost() as i64,
        exec: 32 + t.branch_taken_extra as i64,
        instret: 1,
        ..Default::default()
    }
}

/// Native evaluation of one sample: `(pred, n_neg, n_upd)` — the
/// prediction plus the two data-dependent term counts of the affine
/// law (both zero for OvR, whose accelerated program is branch-free
/// in the data).
fn terms(m: &QuantModel, x_q: &[i32]) -> (i32, i64, i64) {
    let s = infer::scores(m, x_q);
    match m.strategy {
        Strategy::Ovr => (infer::argmax_first(&s) as i32, 0, 0),
        Strategy::Ovo => {
            let n_neg = s.iter().filter(|&&v| v < 0).count() as i64;
            let votes = infer::ovo_votes(m, &s);
            let mut best = votes[0];
            let mut best_i = 0usize;
            let mut n_upd = 0i64;
            for (i, &v) in votes.iter().enumerate().skip(1) {
                if v > best {
                    best = v;
                    best_i = i;
                    n_upd += 1;
                }
            }
            (best_i as i32, n_neg, n_upd)
        }
    }
}

/// The derived, probe-validated cost model of one accelerated
/// `CompiledProgram`: prediction at native speed, cycle bill from the
/// affine law — bit-identical to the block-compiled SoC or the farm's
/// differential audit demotes the config back to full simulation.
#[derive(Debug, Clone)]
pub struct AnalyticModel {
    model: QuantModel,
    base: CostVec,
    dv: CostVec,
    du: CostVec,
}

impl AnalyticModel {
    /// Derive the cost model for an accelerated compiled program.
    ///
    /// Anchors `base` on one measured probe inference, then demands
    /// the law reproduce the simulator **bit-exactly** (prediction and
    /// full `CycleStats`) on fixed corner probes (`[0;F]`, `[15;F]`,
    /// `[7;F]`) and seeded random ones.  Returns `None` for baseline
    /// programs, on any simulation failure, or on any divergence —
    /// callers must then keep simulating.
    pub fn derive(
        m: &QuantModel,
        program: &Arc<CompiledProgram>,
        timing: TimingConfig,
    ) -> Option<AnalyticModel> {
        if program.kind() != ProgramKind::Accelerated {
            return None;
        }
        let mut runner = ProgramRunner::from_compiled(program, timing).ok()?;
        let f = m.n_features;
        let mut probes: Vec<Vec<i32>> = vec![vec![0; f], vec![15; f], vec![7; f]];
        let mut rng = Pcg32::seeded(0xc057_ab1e);
        for _ in 0..3 {
            probes.push((0..f).map(|_| rng.below(16) as i32).collect());
        }
        let dv = vote_detour(&timing);
        let du = argmax_update(&timing);
        let (_, n_neg0, n_upd0) = terms(m, &probes[0]);
        let (_, s0) = runner.run_sample(&probes[0]).ok()?;
        let base = CostVec::from_stats(&s0).sub(dv.scaled(n_neg0)).sub(du.scaled(n_upd0));
        let am = AnalyticModel { model: m.clone(), base, dv, du };
        for x in &probes {
            let (pred, stats) = am.predict(x).ok()?;
            let (sim_pred, sim_stats) = runner.run_sample(x).ok()?;
            if pred != sim_pred || stats != sim_stats {
                return None;
            }
        }
        Some(am)
    }

    /// Classify one sample natively and bill it analytically.  Feature
    /// validation mirrors the simulator's
    /// ([`ProgramRunner::poke_features`]) so the fast path rejects
    /// exactly what the sim path rejects.
    pub fn predict(&self, x_q: &[i32]) -> Result<(i32, CycleStats)> {
        if x_q.len() != self.model.n_features {
            bail!("expected {} features, got {}", self.model.n_features, x_q.len());
        }
        if x_q.iter().any(|&v| !(0..=15).contains(&v)) {
            bail!("features must be 4-bit unsigned");
        }
        let (pred, n_neg, n_upd) = terms(&self.model, x_q);
        let cost = self.base.add(self.dv.scaled(n_neg)).add(self.du.scaled(n_upd));
        let stats = cost
            .to_stats()
            .ok_or_else(|| anyhow!("analytic cost model produced a negative cycle lane"))?;
        Ok((pred, stats))
    }
}

/// Static instruction-count accumulator for the closed-form baseline
/// estimate.
#[derive(Default)]
struct Count {
    /// Retired instructions.
    n: u64,
    /// Immediate-shift extra exec cycles (`slli`/`srli` amounts).
    sh: u64,
    taken: u64,
    loads: u64,
    stores: u64,
}

impl Count {
    fn stats(&self, t: &TimingConfig) -> CycleStats {
        CycleStats {
            fetch: self.n * t.fetch_cost(),
            exec: 32 * self.n + self.sh + t.load_shift_in * self.loads
                + t.branch_taken_extra * self.taken,
            data_mem: self.loads * t.load_cost() + self.stores * t.store_cost(),
            cfu: 0,
            instret: self.n,
            loads: self.loads,
            stores: self.stores,
            cfu_ops: 0,
        }
    }
}

/// Words a `li` expands to (addi, lui, or lui+addi).
fn li_len(v: i32) -> u64 {
    if (-2048..=2047).contains(&v) {
        1
    } else if (v << 20) >> 20 != 0 {
        2
    } else {
        1
    }
}

/// One `call mul32` (jal + body + ret) with multiplier `w`: the loop
/// runs once per bit of the multiplier's width (at least once), adds
/// on set bits, and shifts twice per iteration.
fn mul32_call(c: &mut Count, w: i32) {
    let w = w as u32;
    let l = if w == 0 { 1 } else { (32 - w.leading_zeros()) as u64 };
    let ones = w.count_ones() as u64;
    c.n += 4 + 5 * l + ones;
    c.sh += 2 * l;
    c.taken += (l - ones) + (l - 1);
}

/// Closed-form cycle estimate of the software-only baseline program
/// ([`crate::program::baseline`]) for one sample — no simulation.
/// Exact in intent (every emitted instruction, taken branch, shift
/// amount and memory access is counted from the generator's code
/// shape); tests pin it within 10 % of the simulator.
///
/// Kernel machines have no baseline program (`baseline::build` bails),
/// so this returns all-zero stats for them — callers treat 0 as "no
/// baseline" rather than inventing a bill for a program that cannot
/// exist.
pub fn baseline_estimate(m: &QuantModel, x_q: &[i32], t: &TimingConfig) -> CycleStats {
    if m.is_kernel() {
        return CycleStats::default();
    }
    let k = m.n_classifiers();
    let f = m.n_features;
    let cc = m.n_classes;
    let s = infer::scores(m, x_q);
    let mut c = Count::default();

    // prologue: la x3, li K / li 0 / li F (+ OvO pair/vote setup and
    // the votes-zeroing loop)
    c.n += 6 + li_len(k as i32) + 1 + li_len(f as i32);
    if m.strategy == Strategy::Ovo {
        c.n += 7 + li_len(cc as i32) + 4 * cc as u64;
        c.stores += cc as u64;
        c.taken += cc as u64 - 1;
    }

    let mut best = 0i64;
    for (kk, row) in m.weights.iter().enumerate() {
        // li sum / li j / mv x-ptr
        c.n += 3;
        // per feature: lw,lw / mul32 / add + 3 ptr-and-counter addi + blt
        for &w in row {
            c.n += 7;
            c.loads += 2;
            mul32_call(&mut c, w);
        }
        c.taken += f as u64 - 1; // loop_j back-edges
        // bias: li 15 / lw / mul32 / add / addi
        c.n += 4;
        c.loads += 1;
        mul32_call(&mut c, m.biases[kk]);

        match m.strategy {
            Strategy::Ovr => {
                // strict running max: first classifier always seeds it
                if kk == 0 || s[kk] > best {
                    c.n += if kk == 0 { 3 } else { 4 };
                    c.taken += 1;
                    best = s[kk];
                } else {
                    c.n += 3;
                }
            }
            Strategy::Ovo => {
                // sign test + vote increment (2 loads, 1 store, slli 2)
                if s[kk] >= 0 {
                    c.n += 9;
                    c.taken += 1;
                } else {
                    c.n += 10;
                }
                c.loads += 2;
                c.stores += 1;
                c.sh += 2;
            }
        }
        // addi k / blt loop_k
        c.n += 2;
        if kk + 1 < k {
            c.taken += 1;
        }
    }

    match m.strategy {
        Strategy::Ovr => c.n += 2, // mv a0 / ecall
        Strategy::Ovo => {
            let votes = infer::ovo_votes(m, &s);
            c.n += 3 + li_len(cc as i32); // la votes / li 0 / li C
            let mut vbest = 0i64;
            for (i, &v) in votes.iter().enumerate() {
                if i == 0 || v > vbest {
                    c.n += if i == 0 { 7 } else { 8 };
                    c.taken += 1;
                    vbest = v;
                } else {
                    c.n += 7;
                }
                c.loads += 1;
                if i + 1 < cc {
                    c.taken += 1; // am_loop back-edge
                }
            }
            c.n += 2; // mv a0 / ecall
        }
    }
    c.stats(t)
}

/// The baseline estimate on the calibration probe input (`[7; F]`,
/// matching the farm's calibration run), as total cycles — what the
/// farm seeds `baseline_cycles` with before real calibration lands.
/// 0.0 for kernel models (no baseline program exists — speedup ratios
/// are reported as unknown, never fabricated).
pub fn baseline_estimate_cycles(m: &QuantModel, t: &TimingConfig) -> f64 {
    if m.is_kernel() {
        return 0.0;
    }
    let x = vec![7i32; m.n_features];
    baseline_estimate(m, &x, t).total() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::program::ProgramOpts;

    fn toy(strategy: Strategy) -> QuantModel {
        QuantModel {
            dataset: "toy".into(),
            strategy,
            bits: 4,
            n_classes: 3,
            n_features: 2,
            weights: vec![vec![7, 0], vec![0, 7], vec![-3, -3]],
            biases: vec![0, 0, 5],
            pairs: match strategy {
                Strategy::Ovr => vec![(0, 0), (1, 1), (2, 2)],
                Strategy::Ovo => vec![(0, 1), (0, 2), (1, 2)],
            },
            scale: 1.0,
            kernel: crate::kernel::Kernel::Linear,
            support: Vec::new(),
            kparams: crate::kernel::KernelParams::default(),
        }
    }

    fn toy_kernel(kernel: crate::kernel::Kernel, strategy: Strategy) -> QuantModel {
        let mut m = toy(strategy);
        m.kernel = kernel;
        m.support = vec![vec![0, 0], vec![7, 7], vec![15, 15]];
        // dual rows over the S=3 support set
        m.weights = vec![vec![7, 0, -3], vec![0, 7, 1], vec![-3, -3, 5]];
        m.kparams = match kernel {
            crate::kernel::Kernel::Rbf => {
                crate::kernel::KernelParams { g2_q: 137, ..Default::default() }
            }
            _ => crate::kernel::KernelParams {
                gamma_q: 1165,
                coef0_q: 256,
                degree: 3,
                ..Default::default()
            },
        };
        m
    }

    #[test]
    fn analytic_model_matches_simulation_exactly() {
        let mut rng = Pcg32::seeded(0xfa57);
        for strategy in [Strategy::Ovr, Strategy::Ovo] {
            for timing in [TimingConfig::flexic(), TimingConfig::ideal_mem()] {
                for unroll_limit in [0usize, 1024] {
                    let m = toy(strategy);
                    let c =
                        CompiledProgram::accelerated(&m, ProgramOpts { unroll_limit }).unwrap();
                    let am = AnalyticModel::derive(&m, &c, timing)
                        .expect("derivation must succeed for accel programs");
                    let mut runner = ProgramRunner::from_compiled(&c, timing).unwrap();
                    for _ in 0..12 {
                        let x: Vec<i32> = (0..2).map(|_| rng.below(16) as i32).collect();
                        let (pred, stats) = am.predict(&x).unwrap();
                        let (sp, ss) = runner.run_sample(&x).unwrap();
                        assert_eq!(pred, sp, "{strategy:?} unroll={unroll_limit} x={x:?}");
                        assert_eq!(
                            stats, ss,
                            "bit-exact bill: {strategy:?} unroll={unroll_limit} x={x:?}"
                        );
                    }
                }
            }
        }
    }

    /// The affine law holds for kernel programs too: their only
    /// data-dependent branch sites are the same OvO vote/argmax pair,
    /// so derivation must succeed and bill bit-exactly.
    #[test]
    fn analytic_model_covers_kernel_programs() {
        let mut rng = Pcg32::seeded(0xfa58);
        for kernel in [crate::kernel::Kernel::Rbf, crate::kernel::Kernel::Poly] {
            for strategy in [Strategy::Ovr, Strategy::Ovo] {
                let m = toy_kernel(kernel, strategy);
                let c = CompiledProgram::accelerated(&m, ProgramOpts::default()).unwrap();
                let am = AnalyticModel::derive(&m, &c, TimingConfig::flexic())
                    .expect("derivation must succeed for kernel programs");
                let mut runner =
                    ProgramRunner::from_compiled(&c, TimingConfig::flexic()).unwrap();
                for _ in 0..12 {
                    let x: Vec<i32> = (0..2).map(|_| rng.below(16) as i32).collect();
                    let (pred, stats) = am.predict(&x).unwrap();
                    let (sp, ss) = runner.run_sample(&x).unwrap();
                    assert_eq!(pred, sp, "{kernel} {strategy:?} x={x:?}");
                    assert_eq!(stats, ss, "bit-exact bill: {kernel} {strategy:?} x={x:?}");
                }
            }
        }
    }

    #[test]
    fn kernel_models_have_no_baseline_estimate() {
        let m = toy_kernel(crate::kernel::Kernel::Rbf, Strategy::Ovr);
        let t = TimingConfig::flexic();
        assert_eq!(baseline_estimate_cycles(&m, &t), 0.0);
        assert_eq!(baseline_estimate(&m, &[7, 7], &t).total(), 0);
    }

    #[test]
    fn derive_rejects_baseline_programs() {
        let m = toy(Strategy::Ovr);
        let c = CompiledProgram::baseline(&m).unwrap();
        assert!(AnalyticModel::derive(&m, &c, TimingConfig::ideal_mem()).is_none());
    }

    #[test]
    fn predict_validates_features_like_the_simulator() {
        let m = toy(Strategy::Ovr);
        let c = CompiledProgram::accelerated(&m, ProgramOpts::default()).unwrap();
        let am = AnalyticModel::derive(&m, &c, TimingConfig::ideal_mem()).unwrap();
        assert!(am.predict(&[1]).is_err(), "wrong arity");
        assert!(am.predict(&[16, 0]).is_err(), "out-of-range feature");
        assert!(am.predict(&[-1, 0]).is_err(), "negative feature");
    }

    #[test]
    fn baseline_estimate_tracks_the_simulator() {
        for strategy in [Strategy::Ovr, Strategy::Ovo] {
            let m = toy(strategy);
            let t = TimingConfig::flexic();
            let x = vec![7i32; m.n_features];
            let est = baseline_estimate(&m, &x, &t);
            let (_, sim) = ProgramRunner::baseline(&m, t).unwrap().run_sample(&x).unwrap();
            // memory-access counts are pure code shape: exact
            assert_eq!((est.loads, est.stores), (sim.loads, sim.stores), "{strategy:?}");
            let rel =
                (est.total() as f64 - sim.total() as f64).abs() / sim.total() as f64;
            assert!(
                rel < 0.10,
                "{strategy:?}: estimate {} vs sim {} ({:.1}% off)",
                est.total(),
                sim.total(),
                rel * 100.0
            );
        }
    }

    #[test]
    fn baseline_estimate_scales_with_model_size() {
        let t = TimingConfig::flexic();
        let small = toy(Strategy::Ovr);
        let mut large = toy(Strategy::Ovr);
        large.weights = (0..9).map(|_| vec![7, -7]).collect();
        large.biases = vec![1; 9];
        large.pairs = (0..9).map(|i| (i, i)).collect();
        large.n_classes = 9;
        assert!(
            baseline_estimate_cycles(&large, &t) > 2.0 * baseline_estimate_cycles(&small, &t)
        );
        assert!(baseline_estimate_cycles(&small, &t) > 0.0);
    }
}
