//! Software-only SVM inference (the paper's "w/o accel" configuration).
//!
//! Pure RV32I: every product is a shift-add `mul32` call (SERV has no M
//! extension), scores accumulate in registers, OvR tracks a running
//! strict-maximum, OvO tallies votes in memory and argmaxes them.
//!
//! Register allocation (callee-saved registers are free — bare metal,
//! main never returns):
//!   s0 x-buffer ptr   s1 weight ptr (walks)   s2 bias ptr (walks)
//!   s3 K              s4 k                    s5 best score
//!   s6 best id        s7 F                    s8/s9 pair-i/j ptrs (OvO)
//!   s10 votes base (OvO)
//!   t0 sum            t1 j                    t2 x ptr (walks)
//!   mul32 clobbers a0, a1, t3, t4.

use anyhow::{bail, Result};

use crate::isa::reg::*;
use crate::isa::Asm;
use crate::svm::model::{QuantModel, Strategy};
use crate::svm::infer::XMAX;

use super::{finish, BuiltProgram, ProgramKind};

/// Emit `mul32`: a0 = a0 * a1 (low 32 bits; correct for signed operands
/// mod 2^32).  Iterates while the multiplier has set bits.
fn emit_mul32(a: &mut Asm) {
    a.label("mul32");
    a.mv(T3, A0);
    a.li(A0, 0);
    a.label("mul_loop");
    a.andi(T4, A1, 1);
    a.beq(T4, ZERO, "mul_skip");
    a.add(A0, A0, T3);
    a.label("mul_skip");
    a.slli(T3, T3, 1);
    a.srli(A1, A1, 1);
    a.bne(A1, ZERO, "mul_loop");
    a.ret();
}

/// Build the baseline inference program for a quantized model.
///
/// Kernel machines are accelerator-only: a software shift-add feature
/// map would dwarf the linear baseline without matching any paper
/// configuration, so callers must keep kernel configs off the baseline
/// path (the farm seeds their `baseline_cycles` with 0 = unknown).
pub fn build(m: &QuantModel) -> Result<BuiltProgram> {
    if m.is_kernel() {
        bail!("kernel model {} has no software-only baseline program", m.config_key());
    }
    let k = m.n_classifiers();
    let f = m.n_features;
    let c = m.n_classes;
    let mut a = Asm::new(0);

    // ---- prologue ----
    a.la(S0, "xbuf");
    a.la(S1, "weights");
    a.la(S2, "biases");
    a.li(S3, k as i32);
    a.li(S4, 0);
    a.li(S7, f as i32);
    if m.strategy == Strategy::Ovo {
        a.la(S8, "pairs_i");
        a.la(S9, "pairs_j");
        a.la(S10, "votes");
        // zero the votes array (fresh state every run)
        a.mv(T0, S10);
        a.li(T1, c as i32);
        a.label("zv_loop");
        a.sw(T0, ZERO, 0);
        a.addi(T0, T0, 4);
        a.addi(T1, T1, -1);
        a.bne(T1, ZERO, "zv_loop");
    }

    // ---- per-classifier loop ----
    a.label("loop_k");
    a.li(T0, 0); // sum
    a.li(T1, 0); // j
    a.mv(T2, S0);
    a.label("loop_j");
    a.lw(A0, T2, 0);
    a.lw(A1, S1, 0);
    a.call("mul32");
    a.add(T0, T0, A0);
    a.addi(T2, T2, 4);
    a.addi(S1, S1, 4);
    a.addi(T1, T1, 1);
    a.blt(T1, S7, "loop_j");
    // bias: sum += 15 * b[k]
    a.li(A0, XMAX as i32);
    a.lw(A1, S2, 0);
    a.call("mul32");
    a.add(T0, T0, A0);
    a.addi(S2, S2, 4);

    match m.strategy {
        Strategy::Ovr => {
            // strict-greater running max (first max wins)
            a.beq(S4, ZERO, "update_best");
            a.blt(S5, T0, "update_best");
            a.j("next_k");
            a.label("update_best");
            a.mv(S5, T0);
            a.mv(S6, S4);
            a.label("next_k");
        }
        Strategy::Ovo => {
            // vote: score >= 0 -> pairs_i[k], else pairs_j[k]
            a.bge(T0, ZERO, "vote_i");
            a.lw(T5, S9, 0);
            a.j("do_vote");
            a.label("vote_i");
            a.lw(T5, S8, 0);
            a.label("do_vote");
            a.slli(T5, T5, 2);
            a.add(T5, T5, S10);
            a.lw(T4, T5, 0);
            a.addi(T4, T4, 1);
            a.sw(T5, T4, 0);
            a.addi(S8, S8, 4);
            a.addi(S9, S9, 4);
        }
    }
    a.addi(S4, S4, 1);
    a.blt(S4, S3, "loop_k");

    // ---- epilogue ----
    match m.strategy {
        Strategy::Ovr => {
            a.mv(A0, S6);
            a.ecall();
        }
        Strategy::Ovo => {
            // argmax over votes[0..C], first max wins
            a.la(T6, "votes");
            a.li(T0, 0); // c
            a.li(T1, c as i32);
            a.label("am_loop");
            a.lw(T2, T6, 0);
            a.beq(T0, ZERO, "am_update");
            a.blt(S5, T2, "am_update");
            a.j("am_next");
            a.label("am_update");
            a.mv(S5, T2);
            a.mv(S6, T0);
            a.label("am_next");
            a.addi(T6, T6, 4);
            a.addi(T0, T0, 1);
            a.blt(T0, T1, "am_loop");
            a.mv(A0, S6);
            a.ecall();
        }
    }

    emit_mul32(&mut a);

    // ---- data ----
    let text_words = (a.here() / 4) as usize;
    a.label("xbuf");
    a.zeros(f); // host-poked raw features (one word each, 0..15)
    a.label("weights");
    for row in &m.weights {
        a.words_i32(row);
    }
    a.label("biases");
    a.words_i32(&m.biases);
    if m.strategy == Strategy::Ovo {
        a.label("pairs_i");
        a.words_i32(&m.pairs.iter().map(|p| p.0 as i32).collect::<Vec<_>>());
        a.label("pairs_j");
        a.words_i32(&m.pairs.iter().map(|p| p.1 as i32).collect::<Vec<_>>());
        a.label("votes");
        a.zeros(c);
    }

    let mut built = finish(&a, ProgramKind::Baseline, "xbuf", f)?;
    built.text_words = text_words;
    Ok(built)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::program::run::ProgramRunner;
    use crate::serv::TimingConfig;
    use crate::svm::infer;
    use crate::util::Pcg32;

    fn random_model(rng: &mut Pcg32, strategy: Strategy, bits: u8, c: usize, f: usize) -> QuantModel {
        let qmax = (1i32 << (bits - 1)) - 1;
        let pairs: Vec<(usize, usize)> = match strategy {
            Strategy::Ovr => (0..c).map(|i| (i, i)).collect(),
            Strategy::Ovo => {
                let mut p = vec![];
                for i in 0..c {
                    for j in i + 1..c {
                        p.push((i, j));
                    }
                }
                p
            }
        };
        let k = pairs.len();
        QuantModel {
            dataset: "rand".into(),
            strategy,
            bits,
            n_classes: c,
            n_features: f,
            weights: (0..k)
                .map(|_| (0..f).map(|_| rng.range_i32(-qmax, qmax)).collect())
                .collect(),
            biases: (0..k).map(|_| rng.range_i32(-qmax, qmax)).collect(),
            pairs,
            scale: 1.0,
            kernel: crate::kernel::Kernel::Linear,
            support: Vec::new(),
            kparams: crate::kernel::KernelParams::default(),
        }
    }

    #[test]
    fn kernel_models_have_no_baseline() {
        let mut rng = Pcg32::seeded(3);
        let mut m = random_model(&mut rng, Strategy::Ovr, 4, 2, 3);
        m.kernel = crate::kernel::Kernel::Rbf;
        m.support = vec![vec![1, 2, 3]];
        m.kparams = crate::kernel::KernelParams { g2_q: 137, ..Default::default() };
        assert!(build(&m).is_err());
    }

    /// The SERV-executed baseline program must agree with the native
    /// integer spec on random models and inputs.
    #[test]
    fn baseline_program_matches_native_inference() {
        let mut rng = Pcg32::seeded(0x5eed);
        for strategy in [Strategy::Ovr, Strategy::Ovo] {
            for bits in [4u8, 8, 16] {
                let m = random_model(&mut rng, strategy, bits, 3, 5);
                let mut runner =
                    ProgramRunner::baseline(&m, TimingConfig::ideal_mem()).unwrap();
                for _ in 0..10 {
                    let x: Vec<i32> = (0..5).map(|_| rng.below(16) as i32).collect();
                    let (pred, _) = runner.run_sample(&x).unwrap();
                    assert_eq!(pred, infer::predict(&m, &x), "{strategy:?} w{bits} x={x:?}");
                }
            }
        }
    }

    #[test]
    fn baseline_cycles_scale_with_classifiers() {
        let mut rng = Pcg32::seeded(9);
        let small = random_model(&mut rng, Strategy::Ovr, 8, 2, 4);
        let large = random_model(&mut rng, Strategy::Ovr, 8, 6, 4);
        let x = vec![7i32; 4];
        let t = TimingConfig::flexic();
        let c_small =
            ProgramRunner::baseline(&small, t).unwrap().run_sample(&x).unwrap().1.total();
        let c_large =
            ProgramRunner::baseline(&large, t).unwrap().run_sample(&x).unwrap().1.total();
        assert!(c_large > c_small * 2, "6 classifiers should cost >2x of 2");
    }
}
