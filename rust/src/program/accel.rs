//! Accelerated SVM inference (paper Algorithm 1) using the custom
//! instruction set of Fig. 8, plus the kernel-machine variant (ISSUE 8)
//! on the `K_*` ops of [`crate::isa::ksvm_ops`].
//!
//! Linear: per classifier stream packed (features, weights) word pairs
//! through `SV_Calc{4,8,16}`, finalise with `SV_Res{4,8,16}`.  Kernel:
//! per classifier loop over the support set — `K_ACC` the packed 4-bit
//! lane words (squared distance or dot product), `K_EVAL` the dual
//! coefficient, finalise with `K_RES` carrying the bias.  Both variants
//! share the OvR/OvO result plumbing: OvR reads the running `max_id`
//! from the last result; OvO extracts the sign bit and tallies votes in
//! software.  The linear calc stream is fully unrolled when small
//! (inline-asm style); Dermatology-sized models and all kernel programs
//! keep the loop (only the innermost per-word stream is unrolled — the
//! word count per support vector is tiny).
//!
//! Register allocation (shared; kernel reuses s1 for the dual/bias word
//! walk, s2 for the support-vector base, s7 for the support count):
//!   s0 packed-feature base   s1 weight-word ptr   s2 sv base (kernel)
//!   s3 K                     s4 k                 s7 words/classifier | S
//!   s8/s9 pair ptrs          s10 votes base
//!   t0 result                t1 j | s             t2 feature/sv ptr

use anyhow::Result;

use crate::isa::reg::*;
use crate::isa::{ksvm_ops, svm_ops, Asm, CFU_FUNCT7_KSVM, CFU_FUNCT7_SVM};
use crate::kernel::Kernel;
use crate::obs::Region;
use crate::svm::model::{QuantModel, Strategy};
use crate::svm::pack;

use super::{finish, BuiltProgram, ProgramKind, ProgramOpts};

/// Current text position in words — the unit block entry slots (`pc/4`)
/// are keyed by, so region ranges symbolize profiler samples directly.
fn word(a: &Asm) -> u32 {
    (a.here() / 4) as u32
}

/// Append a `[start, end)` region, skipping empty ranges (several
/// ranges may share a name — the profiler folds them).
fn region(regions: &mut Vec<Region>, name: &'static str, start_word: u32, end_word: u32) {
    if end_word > start_word {
        regions.push(Region { name, start_word, end_word });
    }
}

fn calc_f3(bits: u8) -> u8 {
    match bits {
        4 => svm_ops::SV_CALC4,
        8 => svm_ops::SV_CALC8,
        16 => svm_ops::SV_CALC16,
        _ => unreachable!(),
    }
}

fn res_f3(bits: u8) -> u8 {
    match bits {
        4 => svm_ops::SV_RES4,
        8 => svm_ops::SV_RES8,
        16 => svm_ops::SV_RES16,
        _ => unreachable!(),
    }
}

/// OvO pointer setup + votes zeroing (fresh state every run) — shared
/// prologue tail of the linear and kernel programs.
fn emit_ovo_setup(a: &mut Asm, c: usize) {
    a.la(S8, "pairs_i");
    a.la(S9, "pairs_j");
    a.la(S10, "votes");
    a.mv(T0, S10);
    a.li(T1, c as i32);
    a.label("zv_loop");
    a.sw(T0, ZERO, 0);
    a.addi(T0, T0, 4);
    a.addi(T1, T1, -1);
    a.bne(T1, ZERO, "zv_loop");
}

/// Per-classifier OvO vote on the CFU result in t0: bit 31 set =>
/// negative score => vote pairs_j — shared by both program variants (the
/// analytic cost model's `vote_detour` term is pinned to this shape).
fn emit_ovo_vote(a: &mut Asm, suffix: &str) {
    let vi = format!("vote_i{suffix}");
    let dv = format!("do_vote{suffix}");
    a.srli(T5, T0, 31);
    a.beq(T5, ZERO, &vi);
    a.lw(T5, S9, 0);
    a.j(&dv);
    a.label(&vi);
    a.lw(T5, S8, 0);
    a.label(&dv);
    a.slli(T5, T5, 2);
    a.add(T5, T5, S10);
    a.lw(T4, T5, 0);
    a.addi(T4, T4, 1);
    a.sw(T5, T4, 0);
    a.addi(S8, S8, 4);
    a.addi(S9, S9, 4);
}

/// Result epilogue: OvR reads `max_id` from the last CFU result; OvO
/// argmaxes the vote array (first max wins, matching `argmax_first`).
fn emit_epilogue(a: &mut Asm, strategy: Strategy, c: usize) {
    match strategy {
        Strategy::Ovr => {
            // Algorithm 1: max_id <- result & 0xFF
            a.andi(A0, T0, 0xff);
            a.ecall();
        }
        Strategy::Ovo => {
            a.la(T6, "votes");
            a.li(T0, 0);
            a.li(T1, c as i32);
            a.label("am_loop");
            a.lw(T2, T6, 0);
            a.beq(T0, ZERO, "am_update");
            a.blt(S5, T2, "am_update");
            a.j("am_next");
            a.label("am_update");
            a.mv(S5, T2);
            a.mv(S6, T0);
            a.label("am_next");
            a.addi(T6, T6, 4);
            a.addi(T0, T0, 1);
            a.blt(T0, T1, "am_loop");
            a.mv(A0, S6);
            a.ecall();
        }
    }
}

/// Build the accelerated inference program (dispatches on the model's
/// kernel: linear models use the paper's `SV_*` ops, kernel machines the
/// `K_*` ops).
pub fn build(m: &QuantModel, opts: ProgramOpts) -> Result<BuiltProgram> {
    if m.is_kernel() {
        return build_kernel(m, opts);
    }
    let k = m.n_classifiers();
    let c = m.n_classes;
    let nw = pack::words_per_classifier(m.n_features, m.bits);
    let calc = calc_f3(m.bits);
    let res = res_f3(m.bits);
    let unroll = k * nw <= opts.unroll_limit;
    let mut a = Asm::new(0);
    let mut regions: Vec<Region> = Vec::new();

    // ---- prologue ----
    a.cfu(CFU_FUNCT7_SVM, svm_ops::CREATE_ENV, ZERO, ZERO, ZERO);
    a.la(S0, "fwords");
    a.la(S1, "wwords");
    if m.strategy == Strategy::Ovo {
        emit_ovo_setup(&mut a, c);
    }
    region(&mut regions, "load", 0, word(&a));

    // per-classifier body, emitted once (loop) or K times (unrolled)
    if unroll {
        // straight-line: lw/lw/sv.calc per word, sv.res per classifier
        for kk in 0..k {
            let ds = word(&a);
            for j in 0..nw {
                a.lw(A0, S0, (j * 4) as i32);
                a.lw(A1, S1, ((kk * nw + j) * 4) as i32);
                a.cfu(CFU_FUNCT7_SVM, calc, ZERO, A0, A1);
            }
            a.cfu(CFU_FUNCT7_SVM, res, T0, ZERO, ZERO);
            region(&mut regions, "dot_loop", ds, word(&a));
            if m.strategy == Strategy::Ovo {
                let vs = word(&a);
                emit_ovo_vote(&mut a, &format!("_{kk}"));
                region(&mut regions, "vote", vs, word(&a));
            }
        }
    } else {
        let ds = word(&a);
        a.li(S3, k as i32);
        a.li(S4, 0);
        a.li(S7, nw as i32);
        a.label("loop_k");
        a.li(T1, 0);
        a.mv(T2, S0);
        a.label("loop_j");
        a.lw(A0, T2, 0);
        a.lw(A1, S1, 0);
        a.cfu(CFU_FUNCT7_SVM, calc, ZERO, A0, A1);
        a.addi(T2, T2, 4);
        a.addi(S1, S1, 4);
        a.addi(T1, T1, 1);
        a.blt(T1, S7, "loop_j");
        a.cfu(CFU_FUNCT7_SVM, res, T0, ZERO, ZERO);
        region(&mut regions, "dot_loop", ds, word(&a));
        if m.strategy == Strategy::Ovo {
            let vs = word(&a);
            emit_ovo_vote(&mut a, "");
            region(&mut regions, "vote", vs, word(&a));
        }
        let ts = word(&a); // classifier-loop control backedge
        a.addi(S4, S4, 1);
        a.blt(S4, S3, "loop_k");
        region(&mut regions, "dot_loop", ts, word(&a));
    }

    // ---- epilogue ----
    let es = word(&a);
    emit_epilogue(&mut a, m.strategy, c);
    region(&mut regions, "argmax", es, word(&a));

    // ---- data ----
    let text_words = (a.here() / 4) as usize;
    a.label("fwords");
    a.zeros(nw); // host-poked packed features (incl. the bias lane = 15)
    a.label("wwords");
    a.words(&pack::all_weight_words(m));
    if m.strategy == Strategy::Ovo {
        a.label("pairs_i");
        a.words_i32(&m.pairs.iter().map(|p| p.0 as i32).collect::<Vec<_>>());
        a.label("pairs_j");
        a.words_i32(&m.pairs.iter().map(|p| p.1 as i32).collect::<Vec<_>>());
        a.label("votes");
        a.zeros(c);
    }

    let mut built = finish(&a, ProgramKind::Accelerated, "fwords", nw)?;
    built.text_words = text_words;
    built.regions = regions;
    Ok(built)
}

/// Build the kernel-machine inference program on the `K_*` op family.
///
/// Structure per classifier k: for each support vector s, `K_ACC` the
/// `ceil(F/8)` packed lane-word pairs (unrolled — the per-vector word
/// count is tiny), then `K_EVAL` with `alpha[k][s]`; after the support
/// loop one `K_RES` with `b[k]` yields the sign|max_id result word that
/// feeds the shared OvR/OvO plumbing.  The config registers are
/// programmed in the prologue after `K_ENV` — the SoC re-executes the
/// program from its entry on every rearm, so each run reconfigures.
///
/// The data-dependent cycle structure is identical to the linear
/// program's (only the OvO vote detour and argmax update vary with the
/// input — `K_EVAL`'s compute cycles depend on the configured kernel,
/// not the data), so `cost::AnalyticModel` derives for these programs
/// unchanged.
fn build_kernel(m: &QuantModel, _opts: ProgramOpts) -> Result<BuiltProgram> {
    let k = m.n_classifiers();
    let c = m.n_classes;
    let s = m.n_support();
    let nwf = pack::kernel_words_per_sv(m.n_features);
    let mut a = Asm::new(0);
    let mut regions: Vec<Region> = Vec::new();

    // ---- prologue: full reset, then program the config registers ----
    a.cfu(CFU_FUNCT7_KSVM, ksvm_ops::K_ENV, ZERO, ZERO, ZERO);
    let kind = match m.kernel {
        Kernel::Rbf => ksvm_ops::KIND_RBF,
        Kernel::Poly => ksvm_ops::KIND_POLY,
        Kernel::Linear => unreachable!("build_kernel is only called for kernel models"),
    };
    let cfg = |a: &mut Asm, reg: u32, value: i32| {
        a.li(T3, value);
        a.li(T4, reg as i32);
        a.cfu(CFU_FUNCT7_KSVM, ksvm_ops::K_CFG, ZERO, T3, T4);
    };
    cfg(&mut a, ksvm_ops::kcfg::KIND, kind as i32);
    // GAMMA routes to g2_q (rbf) or gamma_q (poly) by the kind above
    let gamma = match m.kernel {
        Kernel::Rbf => m.kparams.g2_q,
        _ => m.kparams.gamma_q,
    };
    cfg(&mut a, ksvm_ops::kcfg::GAMMA, gamma);
    if m.kernel == Kernel::Poly {
        cfg(&mut a, ksvm_ops::kcfg::COEF0, m.kparams.coef0_q);
        cfg(&mut a, ksvm_ops::kcfg::DEGREE, m.kparams.degree as i32);
    }

    a.la(S0, "fwords");
    a.la(S1, "awords");
    a.la(S2, "svwords");
    if m.strategy == Strategy::Ovo {
        emit_ovo_setup(&mut a, c);
    }
    a.li(S3, k as i32);
    a.li(S4, 0);
    a.li(S7, s as i32);
    region(&mut regions, "load", 0, word(&a));

    // ---- per-classifier / per-support loops ----
    let ss = word(&a);
    a.label("loop_k");
    a.mv(T2, S2); // every classifier re-walks the shared support set
    a.li(T1, 0);
    a.label("loop_s");
    for j in 0..nwf {
        a.lw(A0, S0, (j * 4) as i32);
        a.lw(A1, T2, (j * 4) as i32);
        a.cfu(CFU_FUNCT7_KSVM, ksvm_ops::K_ACC, ZERO, A0, A1);
    }
    a.addi(T2, T2, (nwf * 4) as i32);
    a.lw(A0, S1, 0); // alpha[k][s]
    a.cfu(CFU_FUNCT7_KSVM, ksvm_ops::K_EVAL, ZERO, A0, ZERO);
    a.addi(S1, S1, 4);
    a.addi(T1, T1, 1);
    a.blt(T1, S7, "loop_s");
    region(&mut regions, "sv_loop", ss, word(&a));
    let ps = word(&a);
    a.lw(A0, S1, 0); // b[k]
    a.addi(S1, S1, 4);
    a.cfu(CFU_FUNCT7_KSVM, ksvm_ops::K_RES, T0, A0, ZERO);
    region(&mut regions, "kernel_phi", ps, word(&a));
    if m.strategy == Strategy::Ovo {
        let vs = word(&a);
        emit_ovo_vote(&mut a, "");
        region(&mut regions, "vote", vs, word(&a));
    }
    let ts = word(&a); // classifier-loop control backedge
    a.addi(S4, S4, 1);
    a.blt(S4, S3, "loop_k");
    region(&mut regions, "kernel_phi", ts, word(&a));

    // ---- epilogue ----
    let es = word(&a);
    emit_epilogue(&mut a, m.strategy, c);
    region(&mut regions, "argmax", es, word(&a));

    // ---- data ----
    let text_words = (a.here() / 4) as usize;
    a.label("fwords");
    a.zeros(nwf); // host-poked packed features (8x4-bit lanes, no bias lane)
    a.label("awords");
    for kk in 0..k {
        // per classifier: S dual-coefficient words, then the bias word
        a.words_i32(&m.weights[kk]);
        a.words_i32(&[m.biases[kk]]);
    }
    a.label("svwords");
    a.words(&pack::all_kernel_sv_words(m));
    if m.strategy == Strategy::Ovo {
        a.label("pairs_i");
        a.words_i32(&m.pairs.iter().map(|p| p.0 as i32).collect::<Vec<_>>());
        a.label("pairs_j");
        a.words_i32(&m.pairs.iter().map(|p| p.1 as i32).collect::<Vec<_>>());
        a.label("votes");
        a.zeros(c);
    }

    let mut built = finish(&a, ProgramKind::Accelerated, "fwords", nwf)?;
    built.text_words = text_words;
    built.regions = regions;
    Ok(built)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::program::run::ProgramRunner;
    use crate::serv::TimingConfig;
    use crate::svm::infer;
    use crate::util::Pcg32;

    fn random_model(rng: &mut Pcg32, strategy: Strategy, bits: u8, c: usize, f: usize) -> QuantModel {
        let qmax = (1i32 << (bits - 1)) - 1;
        let pairs: Vec<(usize, usize)> = match strategy {
            Strategy::Ovr => (0..c).map(|i| (i, i)).collect(),
            Strategy::Ovo => {
                let mut p = vec![];
                for i in 0..c {
                    for j in i + 1..c {
                        p.push((i, j));
                    }
                }
                p
            }
        };
        let k = pairs.len();
        QuantModel {
            dataset: "rand".into(),
            strategy,
            bits,
            n_classes: c,
            n_features: f,
            weights: (0..k)
                .map(|_| (0..f).map(|_| rng.range_i32(-qmax, qmax)).collect())
                .collect(),
            biases: (0..k).map(|_| rng.range_i32(-qmax, qmax)).collect(),
            pairs,
            scale: 1.0,
            kernel: Kernel::Linear,
            support: Vec::new(),
            kparams: crate::kernel::KernelParams::default(),
        }
    }

    fn random_kernel_model(
        rng: &mut Pcg32,
        kernel: Kernel,
        strategy: Strategy,
        bits: u8,
        c: usize,
        f: usize,
        s: usize,
    ) -> QuantModel {
        let mut m = random_model(rng, strategy, bits, c, f);
        // weight rows become dual-coefficient rows over the support set
        let qmax = (1i32 << (bits - 1)) - 1;
        let k = m.pairs.len();
        m.weights = (0..k)
            .map(|_| (0..s).map(|_| rng.range_i32(-qmax, qmax)).collect())
            .collect();
        m.kernel = kernel;
        m.support =
            (0..s).map(|_| (0..f).map(|_| rng.below(16) as i32).collect()).collect();
        m.kparams = match kernel {
            Kernel::Rbf => crate::kernel::KernelParams { g2_q: 137, ..Default::default() },
            Kernel::Poly => crate::kernel::KernelParams {
                gamma_q: 1165,
                coef0_q: 256,
                degree: 3,
                ..Default::default()
            },
            Kernel::Linear => unreachable!(),
        };
        m
    }

    /// SERV + accelerator must agree with native inference — loop and
    /// unrolled forms, all precisions, both strategies.
    #[test]
    fn accel_program_matches_native_inference() {
        let mut rng = Pcg32::seeded(0xacce1);
        for strategy in [Strategy::Ovr, Strategy::Ovo] {
            for bits in [4u8, 8, 16] {
                for unroll_limit in [0usize, 1024] {
                    let m = random_model(&mut rng, strategy, bits, 4, 6);
                    let mut runner = ProgramRunner::accelerated(
                        &m,
                        TimingConfig::ideal_mem(),
                        ProgramOpts { unroll_limit },
                    )
                    .unwrap();
                    for _ in 0..8 {
                        let x: Vec<i32> = (0..6).map(|_| rng.below(16) as i32).collect();
                        let (pred, _) = runner.run_sample(&x).unwrap();
                        assert_eq!(
                            pred,
                            infer::predict(&m, &x),
                            "{strategy:?} w{bits} unroll={unroll_limit} x={x:?}"
                        );
                    }
                }
            }
        }
    }

    /// Headline sanity: the accelerated program must beat the baseline
    /// by an order of magnitude under the paper's timing model.
    #[test]
    fn accel_is_much_faster_than_baseline() {
        let mut rng = Pcg32::seeded(21);
        let m = random_model(&mut rng, Strategy::Ovr, 8, 3, 8);
        let x: Vec<i32> = (0..8).map(|_| rng.below(16) as i32).collect();
        let t = TimingConfig::flexic();
        let base = ProgramRunner::baseline(&m, t).unwrap().run_sample(&x).unwrap().1.total();
        let acc = ProgramRunner::accelerated(&m, t, ProgramOpts::default())
            .unwrap()
            .run_sample(&x)
            .unwrap()
            .1
            .total();
        let speedup = base as f64 / acc as f64;
        assert!(speedup > 5.0, "speedup only {speedup:.1}x (base {base}, accel {acc})");
    }

    /// The kernel program on the KSVM CFU must agree with native kernel
    /// inference — both kernels, both strategies, odd feature counts
    /// (partial lane words) included.
    #[test]
    fn kernel_program_matches_native_inference() {
        let mut rng = Pcg32::seeded(0x4e51);
        for kernel in [Kernel::Rbf, Kernel::Poly] {
            for strategy in [Strategy::Ovr, Strategy::Ovo] {
                for f in [4usize, 9] {
                    let m = random_kernel_model(&mut rng, kernel, strategy, 8, 3, f, 5);
                    let mut runner = ProgramRunner::accelerated(
                        &m,
                        TimingConfig::ideal_mem(),
                        ProgramOpts::default(),
                    )
                    .unwrap();
                    for _ in 0..8 {
                        let x: Vec<i32> = (0..f).map(|_| rng.below(16) as i32).collect();
                        let (pred, _) = runner.run_sample(&x).unwrap();
                        assert_eq!(
                            pred,
                            infer::predict(&m, &x),
                            "{kernel} {strategy:?} f={f} x={x:?}"
                        );
                    }
                }
            }
        }
    }

    /// Rearming the SoC re-executes the prologue, so the config
    /// registers survive across samples — repeated runs stay correct
    /// and deterministic.
    #[test]
    fn kernel_program_reconfigures_on_rearm() {
        let mut rng = Pcg32::seeded(0x4e52);
        let m = random_kernel_model(&mut rng, Kernel::Rbf, Strategy::Ovr, 4, 3, 6, 4);
        let mut runner =
            ProgramRunner::accelerated(&m, TimingConfig::flexic(), ProgramOpts::default())
                .unwrap();
        let x = vec![7i32; 6];
        let (p1, s1) = runner.run_sample(&x).unwrap();
        let (p2, s2) = runner.run_sample(&x).unwrap();
        assert_eq!(p1, infer::predict(&m, &x));
        assert_eq!((p1, s1), (p2, s2), "rearm must fully re-init the CFU");
    }
}
