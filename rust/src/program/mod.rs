//! Bare-metal SVM inference programs for the SERV SoC.
//!
//! Two generators produce the exact machine code the paper measures:
//!
//!  * [`baseline`] — pure RV32I software inference.  SERV has no
//!    multiplier (paper §II-B), so every `x*w` product runs through a
//!    shift-add `mul32` routine — the cost the accelerator removes.
//!  * [`accel`] — Algorithm 1: `Create_Env`, a `SV_Calc*` stream over
//!    packed feature/weight words, `SV_Res*` per classifier, and
//!    software vote handling for OvO.
//!
//! Both programs follow the same bare-metal convention: features are
//! host-poked into a fixed buffer before each run, the predicted class
//! id is returned in `a0` via `ecall`.
//!
//! [`run::ProgramRunner`] is the host-side harness that feeds test
//! samples, runs the SoC and collects per-inference cycle statistics.

pub mod accel;
pub mod baseline;
pub mod cost;
pub mod run;

use crate::isa::Asm;
use crate::obs::Region;

/// Which program variant (reports/plots key off this).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProgramKind {
    Baseline,
    Accelerated,
}

impl ProgramKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            ProgramKind::Baseline => "baseline",
            ProgramKind::Accelerated => "accel",
        }
    }
}

/// Generation options.
#[derive(Debug, Clone, Copy)]
pub struct ProgramOpts {
    /// Fully unroll the calc loop of the accelerated program when the
    /// total instruction count stays small (the paper's inline-asm
    /// style).  Loop form is kept for large models (Dermatology).
    pub unroll_limit: usize,
}

impl Default for ProgramOpts {
    fn default() -> Self {
        ProgramOpts { unroll_limit: 128 }
    }
}

/// A generated program image plus the addresses the host needs.
#[derive(Debug, Clone)]
pub struct BuiltProgram {
    pub kind: ProgramKind,
    pub image: Vec<u8>,
    /// Where the host pokes the (raw or packed) feature words.
    pub feature_addr: u32,
    /// Number of feature words the host must write per inference.
    pub n_feature_words: usize,
    /// Static instruction count (text section words).
    pub text_words: usize,
    /// Named text-word ranges for the guest-cycle profiler
    /// (`obs::profile`): block entry slots symbolize through this map.
    /// Empty for generators that don't track regions (baseline) — the
    /// profiler then attributes everything to `"other"`.
    pub regions: Vec<Region>,
}

pub(crate) fn finish(asm: &Asm, kind: ProgramKind, feature_label: &str, n_feature_words: usize)
    -> anyhow::Result<BuiltProgram>
{
    let image = asm.assemble_bytes()?;
    let feature_addr = asm
        .lookup(feature_label)
        .ok_or_else(|| anyhow::anyhow!("program generator did not place {feature_label:?}"))?;
    Ok(BuiltProgram {
        kind,
        image,
        feature_addr,
        n_feature_words,
        text_words: 0,        // patched by generators that track it
        regions: Vec::new(),  // ditto (accel patches, baseline stays empty)
    })
}
