//! FlexIC power/area/energy model (paper §V).
//!
//! The paper synthesises at 52 kHz with the Pragmatic FlexIC Gen3 PDK
//! and reports: SERV 0.94 mW / 18.47 mm², SVM accelerator 0.224 mW /
//! 5.82 mm².  Energy per inference is `cycles × T_clk × P_total` — the
//! baseline rows of Table I also include the (idle) accelerator's
//! static power, which dominates in resistive-pull-up FE logic: the
//! paper's energy column back-derives to exactly
//! `cycles / 52 kHz × (0.94 + 0.224) mW` (checked in tests below
//! against published rows), so energy reduction equals cycle reduction.
//!
//! For ablations (PE lane count sweeps) the model scales the
//! accelerator's power/area linearly in NAND2-equivalent gates —
//! resistive-load nMOS logic burns static power per gate, so linear
//! scaling is the technology-appropriate first-order model [2].

/// Technology/platform constants and component figures.
#[derive(Debug, Clone, Copy)]
pub struct FlexicModel {
    pub clock_hz: f64,
    pub serv_mw: f64,
    pub accel_mw: f64,
    pub serv_area_mm2: f64,
    pub accel_area_mm2: f64,
    /// NAND2-equivalents the reference accelerator maps to (used to
    /// scale power/area for modified accelerators).
    pub accel_ref_gates: u64,
    /// Gen3 FlexIC integration budget (paper [2]: < 20k NAND2).
    pub gate_budget: u64,
}

impl FlexicModel {
    /// The paper's reported configuration.
    pub fn paper() -> Self {
        FlexicModel {
            clock_hz: 52_000.0,
            serv_mw: 0.94,
            accel_mw: 0.224,
            serv_area_mm2: 18.47,
            accel_area_mm2: 5.82,
            accel_ref_gates: 2000,
            gate_budget: 20_000,
        }
    }

    /// Total system power; FE static power keeps the accelerator burning
    /// even when idle, so both configurations pay for it (the fabricated
    /// SoC contains the accelerator whether or not software uses it).
    pub fn total_mw(&self) -> f64 {
        self.serv_mw + self.accel_mw
    }

    /// Energy per inference in mJ for a cycle count.
    pub fn energy_mj(&self, cycles: f64) -> f64 {
        let seconds = cycles / self.clock_hz;
        self.total_mw() * seconds
    }

    /// Latency in seconds.
    pub fn latency_s(&self, cycles: f64) -> f64 {
        cycles / self.clock_hz
    }

    /// Energy reduction (%) of `accel_cycles` vs `base_cycles`; with the
    /// shared power rail this equals the cycle reduction.
    pub fn energy_reduction_pct(&self, base_cycles: f64, accel_cycles: f64) -> f64 {
        100.0 * (1.0 - self.energy_mj(accel_cycles) / self.energy_mj(base_cycles))
    }

    /// Scale the accelerator's power for a variant with a different gate
    /// count (static-power-dominated FE logic: linear in gates).
    pub fn accel_mw_scaled(&self, gates: u64) -> f64 {
        self.accel_mw * gates as f64 / self.accel_ref_gates as f64
    }

    pub fn accel_area_scaled(&self, gates: u64) -> f64 {
        self.accel_area_mm2 * gates as f64 / self.accel_ref_gates as f64
    }

    /// Does a SERV + accelerator system with this many accelerator gates
    /// fit the Gen3 integration budget?
    pub fn fits_budget(&self, accel_gates: u64) -> bool {
        // SERV ≈ 5.5k NAND2 on FPGA-equivalent mapping [8]
        const SERV_GATES: u64 = 5_500;
        SERV_GATES + accel_gates <= self.gate_budget
    }

    /// Battery life in hours at continuous inference (paper §VI: "long
    /// battery life in extreme far-edge use-cases").
    pub fn battery_life_h(&self, battery_mwh: f64) -> f64 {
        battery_mwh / self.total_mw()
    }
}

impl Default for FlexicModel {
    fn default() -> Self {
        Self::paper()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The model must back-derive the paper's own Table-I energy rows.
    #[test]
    fn reproduces_table1_energy_rows() {
        let m = FlexicModel::paper();
        // BS / OvR / 4-bit: 8.16 M cycles -> 183.0 mJ
        assert!((m.energy_mj(8.16e6) - 183.0).abs() < 0.8, "{}", m.energy_mj(8.16e6));
        // BS / OvR / 4-bit accel: 0.26 M cycles -> 5.8 mJ
        assert!((m.energy_mj(0.26e6) - 5.8).abs() < 0.1);
        // Derm / OvO baseline: 61.20 M cycles -> 1372.7 mJ
        assert!((m.energy_mj(61.20e6) - 1372.7).abs() < 5.0);
        // Iris / OvR / 4-bit accel: 0.06 M cycles -> 1.3 mJ
        assert!((m.energy_mj(0.06e6) - 1.3).abs() < 0.1);
    }

    #[test]
    fn energy_reduction_equals_cycle_reduction() {
        let m = FlexicModel::paper();
        let red = m.energy_reduction_pct(8.16e6, 0.26e6);
        assert!((red - 96.8).abs() < 0.1, "{red}");
    }

    #[test]
    fn gate_scaling() {
        let m = FlexicModel::paper();
        assert!((m.accel_mw_scaled(m.accel_ref_gates) - m.accel_mw).abs() < 1e-12);
        assert!((m.accel_mw_scaled(m.accel_ref_gates / 2) - m.accel_mw / 2.0).abs() < 1e-12);
        assert!(m.fits_budget(2000));
        assert!(!m.fits_budget(15_000));
    }

    #[test]
    fn latency_at_52khz() {
        let m = FlexicModel::paper();
        // 52k cycles = 1 second
        assert!((m.latency_s(52_000.0) - 1.0).abs() < 1e-12);
    }
}
