//! Flex-SVM: reproduction of "Support Vector Machines Classification on
//! Bendable RISC-V" — see DESIGN.md for the system inventory and
//! EXPERIMENTS.md for paper-vs-measured results.
//!
//! Built without default features the crate is pure Rust (no XLA
//! toolchain needed); the `pjrt` feature adds the AOT-compiled-HLO
//! serving backend ([`runtime`]).

pub mod accel;
pub mod coordinator;
pub mod engine;
pub mod farm;
pub mod isa;
pub mod kernel;
pub mod net;
pub mod obs;
pub mod power;
pub mod program;
pub mod report;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod serv;
pub mod soc;
pub mod svm;
pub mod testing;
pub mod util;
