//! Flex-SVM: reproduction of "Support Vector Machines Classification on
//! Bendable RISC-V" — see DESIGN.md for the system inventory and
//! EXPERIMENTS.md for paper-vs-measured results.

pub mod accel;
pub mod isa;
pub mod power;
pub mod program;
pub mod coordinator;
pub mod report;
pub mod runtime;
pub mod serv;
pub mod soc;
pub mod svm;
pub mod testing;
pub mod util;
