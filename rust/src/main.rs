//! flexsvm — command-line entry point.
//!
//! Subcommands:
//!   table1        regenerate Table I on the cycle-accurate SERV SoC
//!   area-power    the §V-B area/power paragraph
//!   golden-check  cross-layer bit-exactness sweep over every manifest
//!                 config — linear PE array and RBF/poly kernel machines
//!   sim           run one config's test set on the SoC (baseline+accel)
//!   trace         Fig. 2 life-cycle trace of accelerator instructions
//!   serve         serving loop: local drive, or `--listen` for the wire
//!                 front, `--remote` to execute on remote flexsvm nodes
//!
//! Run with `--help` (or no arguments) for options.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use flexsvm::accel::{pe, svm::SvmAccel, Cfu};
use flexsvm::coordinator::{Backend, Server};
use flexsvm::net::{NetOpts, NetServer, RemoteEngine};
use flexsvm::program::run::ProgramRunner;
use flexsvm::program::ProgramOpts;
use flexsvm::report::{self, table1::render, Table1Opts};
use flexsvm::serv::TimingConfig;
use flexsvm::soc::format_trace_line;
use flexsvm::svm::model::{artifacts_root, Manifest, TestSet};
use flexsvm::svm::{infer, pack};
use flexsvm::util::Args;

const USAGE: &str = "\
flexsvm — SVM classification on Bendable RISC-V (reproduction)

USAGE: flexsvm <subcommand> [options]

  table1       [--datasets bs,derm,iris,seeds,v3] [--limit N] [--attr]
               [--json FILE] [--mem-read N --mem-write N --mem-overhead N]
  area-power
  golden-check
  sim          --config <key> [--limit N]
  trace        --config <key> [--sample I] [--max-lines N]
  serve        [--configs k1,k2] [--requests N] [--backend pjrt|native|accel]
               [--batch-max N] [--linger-us N] [--queue-cap N] [--synthetic]
               [--fastpath] [--audit-rate N]
               [--listen HOST:PORT] [--remote HOST:PORT,...]
               [--net-front pool|epoll] [--event-threads N]
               [--profile-rate N] [--log-level debug|info|warn|error]
               [--log-file events.jsonl] [--slo p99=20ms,avail=99.9]
               --listen serves HTTP (POST /v1/infer, GET /healthz, GET
               /v1/metrics) until ctrl-c, which drains in-flight requests;
               --net-front picks the socket front (default: epoll on Linux
               — a few event threads hold every keep-alive connection;
               pool elsewhere/fallback), --event-threads sizes the epoll
               front (0 = auto);
               --remote executes batches on remote `serve --listen` nodes;
               --synthetic serves built-in tiny models (no artifacts needed);
               --fastpath (accel backend) answers from the analytic cost
               model, auditing every Nth request (--audit-rate, default 16)
               bit-exactly against the simulated SoC;
               --profile-rate N samples the guest-cycle profiler on every
               Nth simulated request (accel backend; 0 = off; GET
               /v1/profile, ?collapsed=1 for flamegraph input);
               --log-level sets the flight-recorder threshold (default
               info; GET /v1/logs), --log-file mirrors events as JSONL;
               --slo sets latency/availability objectives (burn-rate
               gauges in /metrics, verdict in /healthz)
  asm          <file.s> [--out image.bin] [--run] [--max-cycles N]
  rtl-template [--out-dir DIR]     (emit Verilog + C header for the SVM CFU)
  vcd          --config <key> [--sample I] [--out trace.vcd]

Artifacts are read from $FLEXSVM_ARTIFACTS or ./artifacts (make artifacts).
";

fn main() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("table1") => cmd_table1(&args),
        Some("area-power") => {
            print!("{}", report::area_power::render());
            Ok(())
        }
        Some("golden-check") => cmd_golden_check(),
        Some("sim") => cmd_sim(&args),
        Some("trace") => cmd_trace(&args),
        Some("serve") => cmd_serve(&args),
        Some("asm") => cmd_asm(&args),
        Some("rtl-template") => cmd_rtl_template(&args),
        Some("vcd") => cmd_vcd(&args),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn timing_from(args: &Args) -> Result<TimingConfig> {
    let mut t = TimingConfig::flexic();
    t.mem_read = args.u64_or("mem-read", t.mem_read)?;
    t.mem_write = args.u64_or("mem-write", t.mem_write)?;
    t.mem_overhead = args.u64_or("mem-overhead", t.mem_overhead)?;
    Ok(t)
}

fn cmd_table1(args: &Args) -> Result<()> {
    let manifest = Manifest::load(&artifacts_root())?;
    let limit = args.usize_or("limit", 0)?;
    let opts = Table1Opts {
        datasets: args.list_or("datasets", &[]),
        limit: if limit == 0 { None } else { Some(limit) },
        timing: timing_from(args)?,
        program: ProgramOpts::default(),
        verify_accuracy: true,
    };
    let t0 = Instant::now();
    let rows = report::run_table1(&manifest, &opts)?;
    print!("{}", render(&rows, args.flag("attr")));
    eprintln!("({} configs in {:.1}s)", rows.len(), t0.elapsed().as_secs_f64());
    if let Some(path) = args.opt_str("json") {
        std::fs::write(path, report::table1::to_json(&rows).to_string())?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

/// Cross-layer sweep: for every config, golden vectors must agree across
/// native inference, the accelerator model (packed-word emulation), and
/// the SERV-executed accelerated program.
fn cmd_golden_check() -> Result<()> {
    let manifest = Manifest::load(&artifacts_root())?;
    let mut checked = 0;
    for entry in &manifest.configs {
        let model = manifest.model(entry)?;
        let golden = manifest.golden(entry)?;
        let mut runner =
            ProgramRunner::accelerated(&model, TimingConfig::ideal_mem(), ProgramOpts::default())?;
        for (i, x) in golden.x_q.iter().enumerate() {
            // native
            let native_scores = infer::scores(&model, x);
            if native_scores != golden.scores[i] {
                bail!("{}: native scores diverge at sample {i}", entry.key);
            }
            let native_pred = infer::predict(&model, x);
            if native_pred != golden.pred[i] {
                bail!("{}: native pred diverges at sample {i}", entry.key);
            }
            // accelerator model: linear PE array via packed-word
            // emulation, kernel machines via the KSVM op stream
            if model.is_kernel() {
                let scores = flexsvm::testing::ksvm_emulate_scores(&model, x)?;
                if scores != golden.scores[i] {
                    bail!("{}: KSVM emulation diverges at sample {i}", entry.key);
                }
            } else {
                let mode = pack::mode_for_bits(model.bits);
                let fw = pack::feature_words(x, model.bits);
                for (k, &gs) in golden.scores[i].iter().enumerate() {
                    let ww = pack::weight_words(&model, k);
                    let s: i64 = fw.iter().zip(&ww).map(|(&a, &b)| pe::compute(a, b, mode)).sum();
                    if s != gs {
                        bail!("{}: PE emulation diverges at sample {i} classifier {k}", entry.key);
                    }
                }
            }
            // SERV-executed program
            let (pred, _) = runner.run_sample(x)?;
            if pred != golden.pred[i] {
                bail!("{}: SERV program pred diverges at sample {i}", entry.key);
            }
            checked += 1;
        }
    }
    println!(
        "golden-check OK: {checked} samples x 3 layers across {} configs",
        manifest.configs.len()
    );
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<()> {
    let key = args.opt_str("config").ok_or_else(|| anyhow::anyhow!("--config required"))?;
    let manifest = Manifest::load(&artifacts_root())?;
    let entry = manifest.config(key)?;
    let model = manifest.model(entry)?;
    let test = manifest.test_set(&entry.dataset)?;
    let limit = args.usize_or("limit", 0)?;
    let limit = if limit == 0 { None } else { Some(limit) };
    let timing = timing_from(args)?;

    let mut base = ProgramRunner::baseline(&model, timing)?;
    let b = base.run_test_set(&test.x_q, &test.y, limit)?;
    let mut acc = ProgramRunner::accelerated(&model, timing, ProgramOpts::default())?;
    let a = acc.run_test_set(&test.x_q, &test.y, limit)?;
    println!("config {key}: {} samples", b.n_samples);
    println!(
        "  baseline: acc {:.1}%  {:.0} cyc/inf  (fetch {:.0}%  exec {:.0}%  dmem {:.0}%)",
        b.accuracy * 100.0,
        b.cycles_per_inference,
        100.0 * b.agg.fetch as f64 / b.agg.total() as f64,
        100.0 * b.agg.exec as f64 / b.agg.total() as f64,
        100.0 * b.agg.data_mem_share(),
    );
    println!(
        "  accel:    acc {:.1}%  {:.0} cyc/inf  (fetch {:.0}%  exec {:.0}%  dmem {:.0}%  cfu {:.0}%)",
        a.accuracy * 100.0,
        a.cycles_per_inference,
        100.0 * a.agg.fetch as f64 / a.agg.total() as f64,
        100.0 * a.agg.exec as f64 / a.agg.total() as f64,
        100.0 * a.agg.data_mem_share(),
        100.0 * a.agg.cfu as f64 / a.agg.total() as f64,
    );
    println!("  speedup: {:.1}x", b.cycles_per_inference / a.cycles_per_inference);
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    let key = args.opt_str("config").ok_or_else(|| anyhow::anyhow!("--config required"))?;
    let manifest = Manifest::load(&artifacts_root())?;
    let entry = manifest.config(key)?;
    let model = manifest.model(entry)?;
    let test = manifest.test_set(&entry.dataset)?;
    let sample = args.usize_or("sample", 0)?;
    let max_lines = args.usize_or("max-lines", 80)?;
    let timing = TimingConfig::flexic();

    let mut runner = ProgramRunner::accelerated(&model, timing, ProgramOpts::default())?;
    runner.soc_mut().rearm();
    runner.poke_features(&test.x_q[sample])?;
    let mut lines = 0usize;
    let mut cb = |info: &flexsvm::serv::StepInfo| {
        if lines < max_lines {
            println!("{}", format_trace_line(info, &timing));
            lines += 1;
        } else if lines == max_lines {
            println!("... (truncated; --max-lines to extend)");
            lines += 1;
        }
    };
    let r = runner.soc_mut().run_traced(1_000_000_000, Some(&mut cb))?;
    println!(
        "exit: pred={} total {} cycles ({} instructions)",
        r.value(),
        r.stats.total(),
        r.stats.instret
    );
    Ok(())
}

/// Assemble a text program (the framework's bare-metal path without a
/// GCC toolchain); optionally execute it on the SoC with all demo CFUs.
fn cmd_asm(args: &Args) -> Result<()> {
    let path = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: flexsvm asm <file.s> [--run]"))?;
    let src = std::fs::read_to_string(path)?;
    let asm = flexsvm::isa::parse::parse_program(&src)?;
    let image = asm.assemble_bytes()?;
    println!("assembled {} words from {path}", image.len() / 4);
    if let Some(out) = args.opt_str("out") {
        std::fs::write(out, &image)?;
        println!("wrote {out}");
    }
    if args.flag("run") {
        let mut soc = flexsvm::soc::Soc::new(&image, TimingConfig::flexic());
        soc.register_cfu(1, Box::new(SvmAccel::new()))?;
        soc.register_cfu(2, Box::new(flexsvm::accel::mac::MacAccel::new()))?;
        soc.register_cfu(3, Box::new(flexsvm::accel::popcount::PopcountAccel::new()))?;
        let r = soc.run(args.u64_or("max-cycles", 1_000_000_000)?)?;
        println!(
            "exit a0={} after {} cycles ({} instructions, CPI {:.1})",
            r.value(),
            r.stats.total(),
            r.stats.instret,
            r.stats.cpi()
        );
    }
    Ok(())
}

/// Emit the framework's RTL template + C header for the SVM CFU spec
/// (paper §III-D: "a provided template that defines the required
/// interface").
fn cmd_rtl_template(args: &Args) -> Result<()> {
    use flexsvm::accel::rtl_template::CfuSpec;
    let dir = std::path::PathBuf::from(args.str_or("out-dir", "generated_rtl"));
    std::fs::create_dir_all(&dir)?;
    let spec = CfuSpec::svm();
    let v_path = dir.join(format!("{}.v", spec.name));
    let h_path = dir.join(format!("{}.h", spec.name));
    std::fs::write(&v_path, spec.verilog())?;
    std::fs::write(&h_path, spec.c_header())?;
    println!("wrote {} and {}", v_path.display(), h_path.display());
    Ok(())
}

/// Dump the Fig. 1/2 handshake signals of one inference as a VCD file.
fn cmd_vcd(args: &Args) -> Result<()> {
    use flexsvm::soc::vcd::VcdWriter;
    let key = args.opt_str("config").ok_or_else(|| anyhow::anyhow!("--config required"))?;
    let out = args.str_or("out", "trace.vcd");
    let manifest = Manifest::load(&artifacts_root())?;
    let entry = manifest.config(key)?;
    let model = manifest.model(entry)?;
    let test = manifest.test_set(&entry.dataset)?;
    let sample = args.usize_or("sample", 0)?;
    let timing = TimingConfig::flexic();
    let mut runner = ProgramRunner::accelerated(&model, timing, ProgramOpts::default())?;
    runner.soc_mut().rearm();
    runner.poke_features(&test.x_q[sample])?;
    let mut vcd = VcdWriter::new(timing);
    let mut cb = |info: &flexsvm::serv::StepInfo| vcd.record(info);
    let r = runner.soc_mut().run_traced(1_000_000_000, Some(&mut cb))?;
    std::fs::write(out, vcd.finish())?;
    println!("pred={}; wrote {out} ({} cycles of handshake activity)", r.value(), r.stats.total());
    Ok(())
}

/// Flipped by the SIGINT/SIGTERM handler; `serve --listen` polls it so
/// the wire front drains in-flight requests and shuts the coordinator
/// down cleanly (surfacing dispatcher panics) instead of dying
/// mid-batch.
static STOP: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
fn install_ctrlc() -> &'static AtomicBool {
    extern "C" fn on_signal(_sig: i32) {
        STOP.store(true, Ordering::SeqCst);
    }
    // std already links libc on unix; declaring `signal` here keeps the
    // no-new-deps rule (the libc crate is not in the vendor set)
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> isize;
    }
    unsafe {
        signal(2, on_signal); // SIGINT
        signal(15, on_signal); // SIGTERM
    }
    &STOP
}

#[cfg(not(unix))]
fn install_ctrlc() -> &'static AtomicBool {
    // no handler wired: the process stops on plain kill
    &STOP
}

/// Deterministic in-memory models for `--synthetic` (the CI socket
/// smoke runs without artifacts): two mirrored tiny linear 2-class
/// configs plus one config per kernel family.
fn synthetic_models() -> Vec<(String, flexsvm::svm::QuantModel)> {
    use flexsvm::kernel::Kernel;
    use flexsvm::testing::gen;
    vec![
        ("syn_a".to_string(), gen::tiny_model("syn_a", false)),
        ("syn_b".to_string(), gen::tiny_model("syn_b", true)),
        ("syn_rbf".to_string(), gen::tiny_kernel_model("syn_rbf", Kernel::Rbf)),
        ("syn_poly".to_string(), gen::tiny_kernel_model("syn_poly", Kernel::Poly)),
    ]
}

/// Seeded feature streams over the synthetic models, labelled by the
/// native integer spec (so the drive's accuracy check is exact).
fn synthetic_testsets() -> Vec<(String, TestSet)> {
    let mut rng = flexsvm::util::Pcg32::seeded(0x5e1f);
    synthetic_models()
        .into_iter()
        .map(|(key, model)| {
            let x_q: Vec<Vec<i32>> =
                (0..64).map(|_| flexsvm::testing::gen::features(&mut rng, model.n_features)).collect();
            let y: Vec<i32> = x_q.iter().map(|x| infer::predict(&model, x)).collect();
            let t = TestSet {
                name: key.clone(),
                n_classes: model.n_classes,
                n_features: model.n_features,
                x_q,
                y,
            };
            (key, t)
        })
        .collect()
}

fn cmd_serve(args: &Args) -> Result<()> {
    let synthetic = args.flag("synthetic");
    let remotes = args.list_or("remote", &[]);
    let keys: Vec<String> = if synthetic {
        synthetic_models().into_iter().map(|(k, _)| k).collect()
    } else {
        args.list_or("configs", &["iris_ovr_w4", "bs_ovo_w8"])
    };
    let n_requests = args.usize_or("requests", 1000)?;
    // default backend follows the build: pjrt when compiled in, else native
    let backend: Backend = args.str_or("backend", Backend::default_for_build().as_str()).parse()?;
    let farm_opts = flexsvm::farm::FarmOpts {
        fastpath: args.flag("fastpath"),
        audit_rate: args.u64_or("audit-rate", 16)?,
        profile_rate: args.u64_or("profile-rate", 0)?,
        ..Default::default()
    };

    // flight recorder: threshold + optional JSONL sink, set before any
    // serving work so warm-up events land in the ring too
    if let Some(level) = args.opt_str("log-level") {
        flexsvm::obs::log::set_level(level.parse()?);
    }
    if let Some(path) = args.opt_str("log-file") {
        flexsvm::obs::log::set_sink(std::path::Path::new(path))?;
    }
    let slo: Option<flexsvm::obs::SloTargets> =
        args.opt_str("slo").map(|s| s.parse()).transpose()?;

    let builder = Server::builder()
        .batch_max(args.usize_or("batch-max", 64)?)
        .linger(Duration::from_micros(args.u64_or("linger-us", 2000)?))
        .queue_cap(args.usize_or("queue-cap", 1024)?)
        .obs_opts(flexsvm::obs::ObsOpts { slo, ..Default::default() })
        .farm(farm_opts);
    let from_artifacts = remotes.is_empty() && !synthetic;
    let builder = if !remotes.is_empty() {
        // multi-node: batches execute on remote `serve --listen` nodes
        builder.keys(keys.clone()).engine(Box::new(RemoteEngine::new(remotes.clone())?))
    } else if synthetic {
        builder.models(synthetic_models()).backend(backend)
    } else {
        builder.artifacts(artifacts_root(), keys.clone()).backend(backend)
    };
    let server = builder.start().map_err(|e| {
        if from_artifacts {
            anyhow::anyhow!("{e:#}\n(hint: `--synthetic` serves without artifacts)")
        } else {
            e
        }
    })?;

    if let Some(listen) = args.opt_str("listen") {
        let mut net_opts = NetOpts::default();
        if let Some(front) = args.opt_str("net-front") {
            net_opts.front = front.parse().map_err(|e: String| anyhow::anyhow!(e))?;
        }
        net_opts.event_threads = args.usize_or("event-threads", net_opts.event_threads)?;
        return serve_listen(server, listen, &keys, net_opts);
    }

    let client = server.client();
    // drive requests from worker threads using real (or synthetic,
    // natively-labelled) test vectors
    let testsets = if synthetic {
        synthetic_testsets()
    } else {
        // with `--remote` the artifacts may live only on the nodes —
        // the local drive still needs them for test vectors
        let manifest = Manifest::load(&artifacts_root()).map_err(|e| {
            anyhow::anyhow!("{e:#}\n(hint: `--synthetic` drives without local artifacts)")
        })?;
        flexsvm::util::benchkit::load_testsets(&manifest, &keys)?
    };
    let r = flexsvm::util::benchkit::drive_clients(&client, &testsets, n_requests, 4, None)?;
    println!(
        "served {} requests in {:.2}s = {:.0} req/s",
        r.served,
        r.wall.as_secs_f64(),
        r.served as f64 / r.wall.as_secs_f64()
    );
    let metrics = client.metrics()?;
    for (key, m) in &metrics {
        let h = m.latency.as_ref().unwrap();
        println!(
            "  {key}: {} reqs, {} batches (mean {:.1}/batch), p50 {}us p99 {}us",
            m.requests,
            m.batches,
            m.mean_batch(),
            h.quantile_us(0.5),
            h.quantile_us(0.99)
        );
    }
    // any engine whose answers carry sim costs (the farm, or remote
    // nodes running farms) gets the serving energy report
    let engine = client.engine_metrics()?;
    if engine.farm.is_some() || engine.fleet.is_some() {
        let stages = client.obs().stage_snapshot();
        print!(
            "{}",
            report::serving::render(
                &metrics,
                r.wall,
                engine.farm.as_ref(),
                &flexsvm::power::FlexicModel::paper(),
                Some(&stages),
                engine.fleet.as_ref(),
                Some(&r.per_config),
                None,
                client.obs().slo_snapshot().as_ref(),
            )
        );
    }
    server.shutdown()?;
    // keep the accelerator trait demonstrably object-safe in the binary
    let _ = SvmAccel::new().name();
    Ok(())
}

/// `serve --listen`: put the coordinator on a socket and run until
/// ctrl-c, then drain and shut down.
fn serve_listen(server: Server, listen: &str, keys: &[String], opts: NetOpts) -> Result<()> {
    let stop = install_ctrlc();
    let net = NetServer::bind(server, listen, opts)?;
    println!("flexsvm net: listening on {} ({} front)", net.addr(), net.front());
    println!("  configs: {}", keys.join(", "));
    println!(
        "  endpoints: GET /healthz | GET /v1/metrics | GET /metrics | GET /v1/traces | GET /v1/profile | GET /v1/logs | POST /v1/infer"
    );
    println!("  ctrl-c drains in-flight requests and stops");
    while !stop.load(Ordering::SeqCst) {
        std::thread::sleep(Duration::from_millis(150));
    }
    eprintln!("flexsvm net: signal received; draining in-flight requests");
    let m = net.metrics();
    net.shutdown()?;
    println!(
        "flexsvm net: drained and stopped ({} requests served, {} shed, {} bytes out)",
        m.requests, m.shed, m.bytes_out
    );
    Ok(())
}
