//! Cross-layer observability: request spans, stage-level telemetry,
//! and Prometheus exposition.
//!
//! A request is traced from ingress (coordinator `submit` or
//! `POST /v1/infer`) to the answer: every layer records named stage
//! timings (`queue_wait`, `batch_linger`, `dispatch`, `shard_wait`,
//! `execute`, `audit`, `encode`) into the request's own [`StageSet`]
//! — a fixed-size value type, no locking on the hot path — and the
//! completed span is folded into the process-wide [`Obs`] hub exactly
//! once.  The trace id travels the wire (`"trace"` in the JSON body,
//! `X-Trace-Id` header), so a fan-out through
//! [`crate::net::RemoteEngine`] yields one span tree with a child
//! span per node.
//!
//! Retention is 1-in-N sampling plus tail capture (anything slower
//! than the rolling p99 keeps its full span tree) into a bounded ring
//! served at `GET /v1/traces`; stage histograms and the serving
//! counters are also rendered as Prometheus text at `GET /metrics`.
//!
//! Three deeper subsystems ride alongside (ISSUE 10):
//! * [`profile`] — sampled continuous guest-cycle profiler on the
//!   block-compiled SoC hot path, symbolized through the program's
//!   region map and served at `GET /v1/profile`;
//! * [`log`] — the process-global flight-recorder event log
//!   (`GET /v1/logs`, optional JSONL sink);
//! * [`slo`] — per-config latency/availability objectives with
//!   rolling error budgets and multi-window burn-rate verdicts
//!   (`flexsvm_slo_*` gauges, `/healthz` verdict).

pub mod log;
pub mod profile;
pub mod slo;

mod prom;
mod span;
mod store;

pub use profile::{BlockProfiler, ConfigProfile, Region};
pub use prom::{mark_start, render as prom_render};
pub use slo::{SloSnapshot, SloTargets};
pub use span::{Span, Stage, StageSet, TraceId};
pub use store::{merge_stage_maps, Obs, ObsOpts, StageMetrics};
