//! Prometheus text-format exposition (`GET /metrics`), hand-rolled:
//! the text format is a line protocol, so no client library is
//! needed.  Served alongside the existing JSON `/v1/metrics` — same
//! numbers, scrape-friendly shape.
//!
//! Histograms follow the Prometheus convention: cumulative `_bucket`
//! lines with `le` upper bounds (from the fleet-shared
//! [`Histogram::bucket_bounds`] table, in **microseconds**), then
//! `_sum` and `_count`.

use std::collections::{BTreeMap, HashMap};
use std::fmt::Write as _;

use crate::coordinator::metrics::{ConfigMetrics, Histogram};

use super::store::StageMetrics;

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Append one histogram as cumulative `le` buckets + `_sum`/`_count`.
/// `labels` is the pre-rendered label list without braces (may be "").
fn write_hist(out: &mut String, name: &str, labels: &str, h: &Histogram) {
    let sep = if labels.is_empty() { "" } else { "," };
    let mut cum = 0u64;
    for (i, bound) in Histogram::bucket_bounds().iter().enumerate() {
        cum += h.counts()[i];
        let _ = writeln!(out, "{name}_bucket{{{labels}{sep}le=\"{bound}\"}} {cum}");
    }
    let _ = writeln!(out, "{name}_bucket{{{labels}{sep}le=\"+Inf\"}} {}", h.count());
    let _ = writeln!(out, "{name}_sum{{{labels}}} {}", h.sum_us());
    let _ = writeln!(out, "{name}_count{{{labels}}} {}", h.count());
}

/// Render the scrape document: per-config serving counters + latency
/// histograms, per-stage histograms, and process-level counters
/// passed in by the caller (net front, trace retention, farm).
pub fn render(
    configs: &HashMap<String, ConfigMetrics>,
    stages: &BTreeMap<String, StageMetrics>,
    counters: &[(&str, u64)],
) -> String {
    let mut out = String::new();
    // stable output order for tests and scrape diffing
    let ordered: BTreeMap<&str, &ConfigMetrics> =
        configs.iter().map(|(k, v)| (k.as_str(), v)).collect();

    out.push_str("# TYPE flexsvm_requests_total counter\n");
    for (cfg, m) in &ordered {
        let _ = writeln!(
            out,
            "flexsvm_requests_total{{config=\"{}\"}} {}",
            escape_label(cfg),
            m.requests
        );
    }
    out.push_str("# TYPE flexsvm_batches_total counter\n");
    for (cfg, m) in &ordered {
        let _ = writeln!(
            out,
            "flexsvm_batches_total{{config=\"{}\"}} {}",
            escape_label(cfg),
            m.batches
        );
    }
    out.push_str("# TYPE flexsvm_sim_cycles_total counter\n");
    for (cfg, m) in &ordered {
        let _ = writeln!(
            out,
            "flexsvm_sim_cycles_total{{config=\"{}\"}} {}",
            escape_label(cfg),
            m.sim_cycles
        );
    }
    out.push_str("# TYPE flexsvm_energy_mj_total counter\n");
    for (cfg, m) in &ordered {
        let _ = writeln!(
            out,
            "flexsvm_energy_mj_total{{config=\"{}\"}} {}",
            escape_label(cfg),
            m.energy_mj
        );
    }

    out.push_str("# TYPE flexsvm_latency_us histogram\n");
    for (cfg, m) in &ordered {
        if let Some(h) = &m.latency {
            let labels = format!("config=\"{}\"", escape_label(cfg));
            write_hist(&mut out, "flexsvm_latency_us", &labels, h);
        }
    }

    out.push_str("# TYPE flexsvm_stage_us histogram\n");
    for (cfg, sm) in stages {
        for (stage, h) in sm.iter() {
            let labels = format!("config=\"{}\",stage=\"{}\"", escape_label(cfg), stage.name());
            write_hist(&mut out, "flexsvm_stage_us", &labels, h);
        }
    }

    for (name, val) in counters {
        let _ = writeln!(out, "# TYPE flexsvm_{name} counter");
        let _ = writeln!(out, "flexsvm_{name} {val}");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{Obs, ObsOpts, Stage, StageSet};
    use std::time::Duration;

    #[test]
    fn scrape_document_shape() {
        let mut configs = HashMap::new();
        let mut m = ConfigMetrics::new();
        m.requests = 3;
        m.batches = 2;
        m.sim_cycles = 1000;
        m.latency.as_mut().unwrap().record_us(150);
        configs.insert("cfg_a".to_string(), m);

        let obs = Obs::new(ObsOpts::default());
        let mut s = StageSet::new();
        s.set(Stage::QueueWait, 10);
        s.set(Stage::Execute, 120);
        obs.observe("cfg_a", &s, Duration::from_micros(150));

        let text = render(&configs, &obs.stage_snapshot(), &[("net_requests_total", 9)]);
        assert!(text.contains("# TYPE flexsvm_requests_total counter"), "{text}");
        assert!(text.contains("flexsvm_requests_total{config=\"cfg_a\"} 3"), "{text}");
        assert!(text.contains("# TYPE flexsvm_latency_us histogram"), "{text}");
        assert!(text.contains("flexsvm_latency_us_bucket{config=\"cfg_a\",le=\"+Inf\"} 1"));
        assert!(text.contains("flexsvm_latency_us_sum{config=\"cfg_a\"} 150"), "{text}");
        let stage_inf = "flexsvm_stage_us_bucket{config=\"cfg_a\",stage=\"execute\",le=\"+Inf\"} 1";
        assert!(text.contains(stage_inf), "{text}");
        assert!(text.contains("flexsvm_net_requests_total 9"), "{text}");
        // cumulative buckets: the le=200 bucket already includes the
        // 150us sample, and every later bound repeats it
        assert!(text.contains("flexsvm_latency_us_bucket{config=\"cfg_a\",le=\"200\"} 1"));
        assert!(text.contains("flexsvm_latency_us_bucket{config=\"cfg_a\",le=\"100\"} 0"));
    }

    #[test]
    fn label_escaping() {
        assert_eq!(escape_label("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }
}
