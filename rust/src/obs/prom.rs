//! Prometheus text-format exposition (`GET /metrics`), hand-rolled:
//! the text format is a line protocol, so no client library is
//! needed.  Served alongside the existing JSON `/v1/metrics` — same
//! numbers, scrape-friendly shape.
//!
//! Histograms follow the Prometheus convention: cumulative `_bucket`
//! lines with `le` upper bounds (from the fleet-shared
//! [`Histogram::bucket_bounds`] table, in **microseconds**), then
//! `_sum` and `_count`.

use std::collections::{BTreeMap, HashMap};
use std::fmt::Write as _;
use std::sync::OnceLock;
use std::time::Instant;

use crate::coordinator::metrics::{ConfigMetrics, Histogram};

use super::slo::SloSnapshot;
use super::store::StageMetrics;

/// Process-start anchor for `flexsvm_uptime_seconds`.  Server start
/// calls [`mark_start`]; rendering lazily anchors if nobody did.
static START: OnceLock<Instant> = OnceLock::new();

/// Anchor the uptime clock (idempotent; call at server start).
pub fn mark_start() {
    let _ = START.get_or_init(Instant::now);
}

fn uptime_seconds() -> u64 {
    START.get_or_init(Instant::now).elapsed().as_secs()
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Append one histogram as cumulative `le` buckets + `_sum`/`_count`.
/// `labels` is the pre-rendered label list without braces (may be "").
fn write_hist(out: &mut String, name: &str, labels: &str, h: &Histogram) {
    let sep = if labels.is_empty() { "" } else { "," };
    let mut cum = 0u64;
    for (i, bound) in Histogram::bucket_bounds().iter().enumerate() {
        cum += h.counts()[i];
        let _ = writeln!(out, "{name}_bucket{{{labels}{sep}le=\"{bound}\"}} {cum}");
    }
    let _ = writeln!(out, "{name}_bucket{{{labels}{sep}le=\"+Inf\"}} {}", h.count());
    let _ = writeln!(out, "{name}_sum{{{labels}}} {}", h.sum_us());
    let _ = writeln!(out, "{name}_count{{{labels}}} {}", h.count());
}

/// Render the scrape document: per-config serving counters + latency
/// histograms, per-stage histograms, process-level counters passed in
/// by the caller (net front, trace retention, farm), build/uptime
/// hygiene gauges, and — when objectives are configured — the
/// `flexsvm_slo_*` gauge family.
pub fn render(
    configs: &HashMap<String, ConfigMetrics>,
    stages: &BTreeMap<String, StageMetrics>,
    counters: &[(&str, u64)],
    slo: Option<&SloSnapshot>,
) -> String {
    let mut out = String::new();
    // stable output order for tests and scrape diffing
    let ordered: BTreeMap<&str, &ConfigMetrics> =
        configs.iter().map(|(k, v)| (k.as_str(), v)).collect();

    out.push_str("# TYPE flexsvm_build_info gauge\n");
    let _ = writeln!(
        out,
        "flexsvm_build_info{{version=\"{}\"}} 1",
        escape_label(env!("CARGO_PKG_VERSION"))
    );
    out.push_str("# TYPE flexsvm_uptime_seconds gauge\n");
    let _ = writeln!(out, "flexsvm_uptime_seconds {}", uptime_seconds());

    out.push_str("# TYPE flexsvm_requests_total counter\n");
    for (cfg, m) in &ordered {
        let _ = writeln!(
            out,
            "flexsvm_requests_total{{config=\"{}\"}} {}",
            escape_label(cfg),
            m.requests
        );
    }
    out.push_str("# TYPE flexsvm_batches_total counter\n");
    for (cfg, m) in &ordered {
        let _ = writeln!(
            out,
            "flexsvm_batches_total{{config=\"{}\"}} {}",
            escape_label(cfg),
            m.batches
        );
    }
    out.push_str("# TYPE flexsvm_sim_cycles_total counter\n");
    for (cfg, m) in &ordered {
        let _ = writeln!(
            out,
            "flexsvm_sim_cycles_total{{config=\"{}\"}} {}",
            escape_label(cfg),
            m.sim_cycles
        );
    }
    out.push_str("# TYPE flexsvm_energy_mj_total counter\n");
    for (cfg, m) in &ordered {
        let _ = writeln!(
            out,
            "flexsvm_energy_mj_total{{config=\"{}\"}} {}",
            escape_label(cfg),
            m.energy_mj
        );
    }

    out.push_str("# TYPE flexsvm_latency_us histogram\n");
    for (cfg, m) in &ordered {
        if let Some(h) = &m.latency {
            let labels = format!("config=\"{}\"", escape_label(cfg));
            write_hist(&mut out, "flexsvm_latency_us", &labels, h);
        }
    }

    out.push_str("# TYPE flexsvm_stage_us histogram\n");
    for (cfg, sm) in stages {
        for (stage, h) in sm.iter() {
            let labels = format!("config=\"{}\",stage=\"{}\"", escape_label(cfg), stage.name());
            write_hist(&mut out, "flexsvm_stage_us", &labels, h);
        }
    }

    for (name, val) in counters {
        let _ = writeln!(out, "# TYPE flexsvm_{name} counter");
        let _ = writeln!(out, "flexsvm_{name} {val}");
    }

    if let Some(s) = slo {
        out.push_str("# TYPE flexsvm_slo_target_p99_us gauge\n");
        let _ = writeln!(out, "flexsvm_slo_target_p99_us {}", s.targets.p99_us);
        out.push_str("# TYPE flexsvm_slo_target_availability gauge\n");
        let _ = writeln!(out, "flexsvm_slo_target_availability {}", s.targets.avail);
        out.push_str("# TYPE flexsvm_slo_burn_rate gauge\n");
        for c in &s.configs {
            let cfg = escape_label(&c.config);
            let _ = writeln!(
                out,
                "flexsvm_slo_burn_rate{{config=\"{cfg}\",window=\"short\"}} {:.6}",
                c.burn_short
            );
            let _ = writeln!(
                out,
                "flexsvm_slo_burn_rate{{config=\"{cfg}\",window=\"long\"}} {:.6}",
                c.burn_long
            );
        }
        out.push_str("# TYPE flexsvm_slo_window_good gauge\n");
        out.push_str("# TYPE flexsvm_slo_window_total gauge\n");
        for c in &s.configs {
            let cfg = escape_label(&c.config);
            let _ = writeln!(
                out,
                "flexsvm_slo_window_good{{config=\"{cfg}\",window=\"long\"}} {}",
                c.long.0
            );
            let _ = writeln!(
                out,
                "flexsvm_slo_window_total{{config=\"{cfg}\",window=\"long\"}} {}",
                c.long.1
            );
        }
        out.push_str("# TYPE flexsvm_slo_degraded gauge\n");
        for c in &s.configs {
            let _ = writeln!(
                out,
                "flexsvm_slo_degraded{{config=\"{}\"}} {}",
                escape_label(&c.config),
                c.degraded as u8
            );
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{Obs, ObsOpts, Stage, StageSet};
    use std::time::Duration;

    #[test]
    fn scrape_document_shape() {
        let mut configs = HashMap::new();
        let mut m = ConfigMetrics::new();
        m.requests = 3;
        m.batches = 2;
        m.sim_cycles = 1000;
        m.latency.as_mut().unwrap().record_us(150);
        configs.insert("cfg_a".to_string(), m);

        let obs = Obs::new(ObsOpts::default());
        let mut s = StageSet::new();
        s.set(Stage::QueueWait, 10);
        s.set(Stage::Execute, 120);
        obs.observe("cfg_a", &s, Duration::from_micros(150));

        let text = render(&configs, &obs.stage_snapshot(), &[("net_requests_total", 9)], None);
        assert!(text.contains("# TYPE flexsvm_requests_total counter"), "{text}");
        // build/uptime hygiene rides every scrape
        assert!(
            text.contains(&format!(
                "flexsvm_build_info{{version=\"{}\"}} 1",
                env!("CARGO_PKG_VERSION")
            )),
            "{text}"
        );
        assert!(text.contains("# TYPE flexsvm_uptime_seconds gauge"), "{text}");
        assert!(!text.contains("flexsvm_slo_"), "no SLO gauges without targets");
        assert!(text.contains("flexsvm_requests_total{config=\"cfg_a\"} 3"), "{text}");
        assert!(text.contains("# TYPE flexsvm_latency_us histogram"), "{text}");
        assert!(text.contains("flexsvm_latency_us_bucket{config=\"cfg_a\",le=\"+Inf\"} 1"));
        assert!(text.contains("flexsvm_latency_us_sum{config=\"cfg_a\"} 150"), "{text}");
        let stage_inf = "flexsvm_stage_us_bucket{config=\"cfg_a\",stage=\"execute\",le=\"+Inf\"} 1";
        assert!(text.contains(stage_inf), "{text}");
        assert!(text.contains("flexsvm_net_requests_total 9"), "{text}");
        // cumulative buckets: the le=200 bucket already includes the
        // 150us sample, and every later bound repeats it
        assert!(text.contains("flexsvm_latency_us_bucket{config=\"cfg_a\",le=\"200\"} 1"));
        assert!(text.contains("flexsvm_latency_us_bucket{config=\"cfg_a\",le=\"100\"} 0"));
    }

    #[test]
    fn label_escaping() {
        assert_eq!(escape_label("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }

    #[test]
    fn slo_gauges_render_when_targets_are_set() {
        use crate::obs::slo::ConfigSlo;
        let snap = SloSnapshot {
            targets: "p99=20ms,avail=99.9".parse().unwrap(),
            configs: vec![ConfigSlo {
                config: "syn_a".into(),
                short: (9, 10),
                long: (59, 60),
                burn_short: 100.0,
                burn_long: 16.66,
                degraded: true,
            }],
        };
        let text = render(&HashMap::new(), &BTreeMap::new(), &[], Some(&snap));
        assert!(text.contains("flexsvm_slo_target_p99_us 20000"), "{text}");
        assert!(text.contains("flexsvm_slo_target_availability 99.9"), "{text}");
        assert!(
            text.contains("flexsvm_slo_burn_rate{config=\"syn_a\",window=\"short\"} 100.0"),
            "{text}"
        );
        assert!(
            text.contains("flexsvm_slo_window_total{config=\"syn_a\",window=\"long\"} 60"),
            "{text}"
        );
        assert!(text.contains("flexsvm_slo_degraded{config=\"syn_a\"} 1"), "{text}");
    }
}
