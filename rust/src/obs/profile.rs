//! Sampled continuous guest-cycle profiler.
//!
//! The block-compiled SoC hot path ([`crate::soc::block`]) already
//! charges cycles block-at-a-time; profiling piggybacks on that: a
//! [`BlockProfiler`] records one `(entry slot, cycles)` bump per
//! executed basic block (CFU cycles kept separate, since the CFU is a
//! meaningful "region" of its own), and the program generator emits a
//! [`Region`] map so raw `pc/4` block slots symbolize to program
//! regions (load / dot-product loop / kernel phi / vote / argmax).
//!
//! The conservation contract (DESIGN.md §5): a profiled run attributes
//! **every** cycle — `BlockProfiler::attributed()` equals the run's
//! `CycleStats::total()` bit-exactly.  This is what makes per-region
//! percentages trustworthy, and it is proptested over random models ×
//! bits × kernels × timing.
//!
//! Per-run profiles are absorbed into a per-config [`ConfigProfile`]
//! in the farm (sampled 1-in-N requests, `FarmOpts::profile_rate`),
//! merged across shards and — via `net::wire` — across the fleet, and
//! served at `GET /v1/profile` (top-N hot regions + a collapsed-stack
//! text form for flamegraph tooling).

use std::collections::BTreeMap;

/// A named half-open word range `[start_word, end_word)` of compiled
/// program text.  Several ranges may share a name (e.g. an unrolled
/// vote sequence emitted per class pair); symbolization folds them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Region {
    pub name: &'static str,
    pub start_word: u32,
    pub end_word: u32,
}

impl Region {
    pub fn contains(&self, slot: u32) -> bool {
        self.start_word <= slot && slot < self.end_word
    }
}

/// Name for a block-entry slot under a region map.  Slots outside
/// every region (or any slot when the program carries no map, e.g. the
/// shift-add baseline) fall into `"other"` — never dropped, so the
/// conservation contract survives symbolization.
pub fn symbolize(slot: u32, regions: &[Region]) -> &'static str {
    regions.iter().find(|r| r.contains(slot)).map(|r| r.name).unwrap_or("other")
}

/// Pseudo-region holding CFU busy cycles (they belong to the custom
/// function unit, not to any text range).
pub const CFU_REGION: &str = "cfu";

/// Raw per-run cycle attribution: one counter bump per executed basic
/// block, keyed by the block's entry slot (`pc/4`).
#[derive(Debug, Clone, Default)]
pub struct BlockProfiler {
    blocks: BTreeMap<u32, u64>,
    cfu: u64,
}

impl BlockProfiler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Charge one executed block: `cycles` non-CFU cycles to its entry
    /// slot, `cfu` cycles to the CFU pseudo-region.
    pub fn record(&mut self, slot: u32, cycles: u64, cfu: u64) {
        *self.blocks.entry(slot).or_insert(0) += cycles;
        self.cfu += cfu;
    }

    /// Every cycle this run attributed anywhere.  The conservation
    /// contract: equals the run's `CycleStats::total()` bit-exactly.
    pub fn attributed(&self) -> u64 {
        self.blocks.values().sum::<u64>() + self.cfu
    }

    pub fn cfu_cycles(&self) -> u64 {
        self.cfu
    }

    pub fn blocks(&self) -> &BTreeMap<u32, u64> {
        &self.blocks
    }
}

/// Aggregated, symbolized profile for one served config.  Built by
/// absorbing sampled [`BlockProfiler`] runs shard-side; merged across
/// shards / nodes with [`merge`](Self::merge) (both directions are
/// plain counter adds, so fleet aggregation is order-independent).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ConfigProfile {
    /// Runs that were profiled (not total requests — sampling).
    pub sampled_runs: u64,
    /// Total cycles across all sampled runs (== sum of `regions`).
    pub total_cycles: u64,
    /// Cycles per region name, `"other"` + [`CFU_REGION`] included.
    pub regions: BTreeMap<String, u64>,
}

impl ConfigProfile {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn is_empty(&self) -> bool {
        self.sampled_runs == 0
    }

    /// Fold one profiled run in, symbolizing block slots through the
    /// program's region map.
    pub fn absorb(&mut self, run: &BlockProfiler, regions: &[Region]) {
        self.sampled_runs += 1;
        for (&slot, &cycles) in run.blocks() {
            *self.regions.entry(symbolize(slot, regions).to_string()).or_insert(0) += cycles;
        }
        if run.cfu_cycles() > 0 {
            *self.regions.entry(CFU_REGION.to_string()).or_insert(0) += run.cfu_cycles();
        }
        self.total_cycles += run.attributed();
    }

    /// Counter-add another profile (shard → config, node → fleet).
    pub fn merge(&mut self, other: &ConfigProfile) {
        self.sampled_runs += other.sampled_runs;
        self.total_cycles += other.total_cycles;
        for (name, cycles) in &other.regions {
            *self.regions.entry(name.clone()).or_insert(0) += cycles;
        }
    }

    /// Top-`n` regions by cycles: `(name, cycles, pct_of_total)`.
    pub fn hot_regions(&self, n: usize) -> Vec<(String, u64, f64)> {
        let mut v: Vec<(String, u64)> =
            self.regions.iter().map(|(k, &c)| (k.clone(), c)).collect();
        v.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
        v.truncate(n);
        let total = self.total_cycles.max(1) as f64;
        v.into_iter().map(|(k, c)| (k, c, 100.0 * c as f64 / total)).collect()
    }

    /// Collapsed-stack lines (`flexsvm;<config>;<region> <cycles>`) —
    /// the text format flamegraph tooling folds directly.
    pub fn collapsed_stack(&self, config: &str, out: &mut String) {
        for (name, cycles) in &self.regions {
            out.push_str("flexsvm;");
            out.push_str(config);
            out.push(';');
            out.push_str(name);
            out.push(' ');
            out.push_str(&cycles.to_string());
            out.push('\n');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn map() -> Vec<Region> {
        vec![
            Region { name: "load", start_word: 0, end_word: 4 },
            Region { name: "dot_loop", start_word: 4, end_word: 10 },
            Region { name: "vote", start_word: 10, end_word: 12 },
        ]
    }

    #[test]
    fn symbolize_maps_slots_and_falls_back_to_other() {
        let m = map();
        assert_eq!(symbolize(0, &m), "load");
        assert_eq!(symbolize(4, &m), "dot_loop");
        assert_eq!(symbolize(9, &m), "dot_loop");
        assert_eq!(symbolize(10, &m), "vote");
        assert_eq!(symbolize(12, &m), "other");
        assert_eq!(symbolize(3, &[]), "other", "no map: everything is other");
    }

    #[test]
    fn profiler_attribution_is_the_sum_of_its_parts() {
        let mut p = BlockProfiler::new();
        p.record(4, 100, 8);
        p.record(4, 50, 0);
        p.record(0, 7, 0);
        assert_eq!(p.attributed(), 100 + 50 + 7 + 8);
        assert_eq!(p.cfu_cycles(), 8);
        assert_eq!(p.blocks()[&4], 150);
    }

    #[test]
    fn absorb_symbolizes_and_conserves_totals() {
        let mut p = BlockProfiler::new();
        p.record(0, 10, 0); // load
        p.record(4, 200, 32); // dot_loop + cfu
        p.record(10, 15, 0); // vote
        p.record(40, 5, 0); // other
        let mut cp = ConfigProfile::new();
        cp.absorb(&p, &map());
        assert_eq!(cp.sampled_runs, 1);
        assert_eq!(cp.total_cycles, p.attributed());
        assert_eq!(cp.regions["dot_loop"], 200);
        assert_eq!(cp.regions[CFU_REGION], 32);
        assert_eq!(cp.regions["other"], 5);
        assert_eq!(cp.regions.values().sum::<u64>(), cp.total_cycles);
    }

    #[test]
    fn merge_is_a_plain_counter_add() {
        let mut a = ConfigProfile::new();
        let mut b = ConfigProfile::new();
        let mut p = BlockProfiler::new();
        p.record(4, 100, 0);
        a.absorb(&p, &map());
        b.absorb(&p, &map());
        b.absorb(&p, &map());
        a.merge(&b);
        assert_eq!(a.sampled_runs, 3);
        assert_eq!(a.total_cycles, 300);
        assert_eq!(a.regions["dot_loop"], 300);
    }

    #[test]
    fn hot_regions_rank_by_cycles_with_pct() {
        let mut cp = ConfigProfile::new();
        let mut p = BlockProfiler::new();
        p.record(0, 10, 0);
        p.record(4, 80, 10);
        cp.absorb(&p, &map());
        let hot = cp.hot_regions(2);
        assert_eq!(hot.len(), 2);
        assert_eq!(hot[0].0, "dot_loop");
        assert_eq!(hot[0].1, 80);
        assert!((hot[0].2 - 80.0).abs() < 1e-9);
        assert_eq!(hot[1].0, CFU_REGION);
    }

    #[test]
    fn collapsed_stack_renders_flamegraph_lines() {
        let mut cp = ConfigProfile::new();
        let mut p = BlockProfiler::new();
        p.record(4, 42, 0);
        cp.absorb(&p, &map());
        let mut s = String::new();
        cp.collapsed_stack("iris_w4", &mut s);
        assert_eq!(s, "flexsvm;iris_w4;dot_loop 42\n");
    }
}
