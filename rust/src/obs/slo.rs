//! Serving-level objectives: rolling error budgets and burn-rate
//! alerts per served config.
//!
//! An SLO here is two targets: a p99-style latency bound (a request
//! slower than `p99_us` is "bad" even if it succeeded) and an
//! availability percentage (the fraction of requests that must be
//! good).  Every completed request is scored good/bad into per-config
//! rings of one-second buckets; evaluation reads two rolling windows —
//! short (10 s, "is it burning *now*") and long (60 s, "has it been
//! burning") — and computes each window's **burn rate**: the observed
//! bad-request rate divided by the budgeted rate `1 - avail`.  Burn 1.0
//! means the error budget is being consumed exactly as fast as it
//! refills; the classic multi-window rule says a config is degraded
//! only when *both* windows burn above threshold (a lone short spike
//! or a long-gone incident doesn't page).
//!
//! The verdict (`ok | degraded(reasons)`) surfaces in `GET /healthz`,
//! the per-config numbers as `flexsvm_slo_*` gauges in `/metrics`, and
//! as an SLO table in `report::serving`.

use std::str::FromStr;
use std::time::Duration;

use anyhow::{bail, Context, Result};

/// Short ("burning now") window, seconds.
pub const SHORT_WINDOW_S: u64 = 10;
/// Long ("has been burning") window, seconds.
pub const LONG_WINDOW_S: u64 = 60;
/// One-second buckets; must exceed the long window so stale buckets
/// can be detected by epoch instead of zeroed on a timer.
const N_BUCKETS: u64 = 64;
/// Both windows must burn at or above this to degrade the verdict.
pub const BURN_ALERT: f64 = 1.0;

/// The objectives one config is held to (CLI `--slo p99=20ms,avail=99.9`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloTargets {
    /// Latency bound in microseconds: a slower answer is "bad".
    pub p99_us: u64,
    /// Availability target in percent (e.g. `99.9`): at least this
    /// fraction of requests must be good.
    pub avail: f64,
}

impl SloTargets {
    /// Budgeted bad-request fraction (`1 - avail`), floored so a
    /// `100%` target doesn't divide by zero.
    pub fn budget(&self) -> f64 {
        ((100.0 - self.avail) / 100.0).max(1e-9)
    }

    /// Is one request within objective?
    pub fn good(&self, ok: bool, latency: Duration) -> bool {
        ok && latency.as_micros() as u64 <= self.p99_us
    }
}

fn parse_duration_us(s: &str) -> Result<u64> {
    let (num, mult) = if let Some(v) = s.strip_suffix("ms") {
        (v, 1_000.0)
    } else if let Some(v) = s.strip_suffix("us") {
        (v, 1.0)
    } else if let Some(v) = s.strip_suffix('s') {
        (v, 1_000_000.0)
    } else {
        (s, 1.0) // bare number = microseconds
    };
    let v: f64 = num.parse().with_context(|| format!("bad duration {s:?}"))?;
    if v < 0.0 {
        bail!("negative duration {s:?}");
    }
    Ok((v * mult) as u64)
}

impl FromStr for SloTargets {
    type Err = anyhow::Error;

    /// `p99=20ms,avail=99.9` (either part optional; defaults
    /// `p99=50ms`, `avail=99.0`).
    fn from_str(s: &str) -> Result<SloTargets> {
        let mut t = SloTargets { p99_us: 50_000, avail: 99.0 };
        for part in s.split(',').filter(|p| !p.is_empty()) {
            let (k, v) = part
                .split_once('=')
                .with_context(|| format!("expected key=value in SLO spec, got {part:?}"))?;
            match k.trim() {
                "p99" => t.p99_us = parse_duration_us(v.trim())?,
                "avail" => {
                    t.avail = v.trim().parse().with_context(|| format!("bad avail {v:?}"))?;
                    if !(0.0..=100.0).contains(&t.avail) {
                        bail!("avail must be a percentage in [0,100], got {v}");
                    }
                }
                other => bail!("unknown SLO key {other:?} (p99|avail)"),
            }
        }
        Ok(t)
    }
}

#[derive(Debug, Clone, Copy, Default)]
struct Bucket {
    /// Absolute second this bucket last counted for; a mismatch on
    /// access means the bucket is stale and reads/writes as zero.
    epoch_s: u64,
    good: u64,
    total: u64,
}

/// Per-config rolling good/total counts in one-second buckets.
#[derive(Debug, Clone)]
pub struct SloTracker {
    buckets: Vec<Bucket>,
}

impl Default for SloTracker {
    fn default() -> Self {
        SloTracker { buckets: vec![Bucket::default(); N_BUCKETS as usize] }
    }
}

impl SloTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Score one completed request at absolute second `now_s`.
    pub fn record(&mut self, now_s: u64, good: bool) {
        let b = &mut self.buckets[(now_s % N_BUCKETS) as usize];
        if b.epoch_s != now_s {
            *b = Bucket { epoch_s: now_s, good: 0, total: 0 };
        }
        b.total += 1;
        b.good += good as u64;
    }

    /// `(good, total)` over the trailing `window_s` seconds ending at
    /// `now_s` (inclusive).
    pub fn window(&self, now_s: u64, window_s: u64) -> (u64, u64) {
        let (mut good, mut total) = (0u64, 0u64);
        for back in 0..window_s.min(N_BUCKETS) {
            let Some(s) = now_s.checked_sub(back) else { break };
            let b = &self.buckets[(s % N_BUCKETS) as usize];
            if b.epoch_s == s {
                good += b.good;
                total += b.total;
            }
        }
        (good, total)
    }
}

/// One config's SLO evaluation at a point in time.
#[derive(Debug, Clone)]
pub struct ConfigSlo {
    pub config: String,
    /// `(good, total)` over the short / long windows.
    pub short: (u64, u64),
    pub long: (u64, u64),
    /// Error-budget burn rates (1.0 = budget consumed exactly as fast
    /// as it refills); 0 when the window saw no traffic.
    pub burn_short: f64,
    pub burn_long: f64,
    pub degraded: bool,
}

/// Evaluate one config: burn per window, degraded when both windows
/// burn at or above [`BURN_ALERT`].
pub fn evaluate(config: &str, tracker: &SloTracker, targets: &SloTargets, now_s: u64) -> ConfigSlo {
    let burn = |(good, total): (u64, u64)| -> f64 {
        if total == 0 {
            return 0.0;
        }
        let err = (total - good) as f64 / total as f64;
        err / targets.budget()
    };
    let short = tracker.window(now_s, SHORT_WINDOW_S);
    let long = tracker.window(now_s, LONG_WINDOW_S);
    let (burn_short, burn_long) = (burn(short), burn(long));
    ConfigSlo {
        config: config.to_string(),
        short,
        long,
        burn_short,
        burn_long,
        degraded: burn_short >= BURN_ALERT && burn_long >= BURN_ALERT,
    }
}

/// Fleet-facing evaluation of every config under one set of targets.
#[derive(Debug, Clone)]
pub struct SloSnapshot {
    pub targets: SloTargets,
    pub configs: Vec<ConfigSlo>,
}

impl SloSnapshot {
    pub fn healthy(&self) -> bool {
        self.configs.iter().all(|c| !c.degraded)
    }

    /// Human-readable reasons for every degraded config (empty = ok).
    pub fn reasons(&self) -> Vec<String> {
        self.configs
            .iter()
            .filter(|c| c.degraded)
            .map(|c| {
                format!(
                    "{}: burn {:.1}x/{:.1}x (short/long) vs p99<={}us avail>={}%",
                    c.config, c.burn_short, c.burn_long, self.targets.p99_us, self.targets.avail
                )
            })
            .collect()
    }

    /// `ok` or `degraded(reason; reason)` — the `/healthz` verdict.
    pub fn verdict(&self) -> String {
        if self.healthy() {
            "ok".to_string()
        } else {
            format!("degraded({})", self.reasons().join("; "))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn targets_parse_with_units_and_defaults() {
        let t: SloTargets = "p99=20ms,avail=99.9".parse().unwrap();
        assert_eq!(t.p99_us, 20_000);
        assert!((t.avail - 99.9).abs() < 1e-12);
        let t: SloTargets = "p99=1500us".parse().unwrap();
        assert_eq!(t.p99_us, 1_500);
        assert!((t.avail - 99.0).abs() < 1e-12, "avail defaults");
        let t: SloTargets = "p99=2s".parse().unwrap();
        assert_eq!(t.p99_us, 2_000_000);
        let t: SloTargets = "avail=95".parse().unwrap();
        assert_eq!(t.p99_us, 50_000, "p99 defaults");
        assert!("p99=oops".parse::<SloTargets>().is_err());
        assert!("avail=120".parse::<SloTargets>().is_err());
        assert!("spice=11".parse::<SloTargets>().is_err());
    }

    #[test]
    fn good_requires_both_success_and_latency() {
        let t: SloTargets = "p99=10ms,avail=99".parse().unwrap();
        assert!(t.good(true, Duration::from_millis(5)));
        assert!(!t.good(true, Duration::from_millis(50)), "slow success is bad");
        assert!(!t.good(false, Duration::from_millis(1)), "fast failure is bad");
    }

    #[test]
    fn windows_roll_and_stale_buckets_read_zero() {
        let mut tr = SloTracker::new();
        for s in 100..110 {
            tr.record(s, true);
            tr.record(s, false);
        }
        assert_eq!(tr.window(109, SHORT_WINDOW_S), (10, 20));
        // a long gap: those buckets are stale at the new epoch
        tr.record(500, true);
        assert_eq!(tr.window(500, SHORT_WINDOW_S), (1, 1));
        assert_eq!(tr.window(500, LONG_WINDOW_S), (1, 1));
    }

    #[test]
    fn bucket_reuse_across_ring_wraps() {
        let mut tr = SloTracker::new();
        tr.record(7, false);
        // same ring slot, N_BUCKETS seconds later: must not leak
        tr.record(7 + N_BUCKETS, true);
        assert_eq!(tr.window(7 + N_BUCKETS, 1), (1, 1));
    }

    #[test]
    fn burn_rate_needs_both_windows_to_degrade() {
        let targets: SloTargets = "p99=10ms,avail=90".parse().unwrap(); // budget 10%
        let mut tr = SloTracker::new();
        // long window healthy, short window on fire
        for s in 0..50 {
            for _ in 0..10 {
                tr.record(s, true);
            }
        }
        for s in 50..60 {
            for _ in 0..10 {
                tr.record(s, false);
            }
        }
        let e = evaluate("cfg", &tr, &targets, 59);
        assert!(e.burn_short >= BURN_ALERT, "short window is burning: {}", e.burn_short);
        // long window: 100 bad / 600 total = 16.7% err over 10% budget
        assert!(e.burn_long > 1.0);
        assert!(e.degraded);

        // a lone ancient incident must not page
        let mut tr = SloTracker::new();
        for _ in 0..100 {
            tr.record(0, false);
        }
        for s in 50..60 {
            tr.record(s, true);
        }
        let e = evaluate("cfg", &tr, &targets, 59);
        assert!(e.burn_short < BURN_ALERT);
        assert!(!e.degraded, "short window recovered: no page");
    }

    #[test]
    fn snapshot_verdict_renders_reasons() {
        let targets: SloTargets = "p99=10ms,avail=99".parse().unwrap();
        let ok = ConfigSlo {
            config: "a".into(),
            short: (10, 10),
            long: (60, 60),
            burn_short: 0.0,
            burn_long: 0.0,
            degraded: false,
        };
        let bad = ConfigSlo {
            config: "b".into(),
            short: (0, 10),
            long: (0, 60),
            burn_short: 100.0,
            burn_long: 100.0,
            degraded: true,
        };
        let snap = SloSnapshot { targets, configs: vec![ok.clone()] };
        assert!(snap.healthy());
        assert_eq!(snap.verdict(), "ok");
        let snap = SloSnapshot { targets, configs: vec![ok, bad] };
        assert!(!snap.healthy());
        assert!(snap.verdict().starts_with("degraded(b: burn"));
    }

    #[test]
    fn no_traffic_is_healthy() {
        let targets: SloTargets = "p99=10ms,avail=99.9".parse().unwrap();
        let e = evaluate("idle", &SloTracker::new(), &targets, 1000);
        assert_eq!(e.burn_short, 0.0);
        assert!(!e.degraded, "an idle config has burned no budget");
    }
}
