//! Flight-recorder event log: structured, leveled, trace-correlated.
//!
//! Lifecycle events that previously vanished into bare counters —
//! audit mismatch → config poisoned, fast-path activation, SMC
//! re-translation, shard spill, node down, admission shed, drain
//! start/end — are recorded here as structured [`Event`]s: a bounded
//! in-memory ring (newest win, served at `GET /v1/logs?n=&level=&trace=`)
//! plus an optional JSONL file sink (`--log-file`) that survives the
//! process for post-mortems.
//!
//! The log is a process-global (one flight recorder per process, like
//! the airframe it is named after): emit sites live in `soc/`, `farm/`,
//! `net/` and `coordinator/` and must not thread a handle through every
//! layer.  The level gate is a single relaxed atomic load, and
//! [`emit_fmt`] takes a closure so disabled events never format.

use std::collections::VecDeque;
use std::fmt;
use std::fs::File;
use std::io::Write as _;
use std::path::Path;
use std::str::FromStr;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::Mutex;
use std::time::{SystemTime, UNIX_EPOCH};

use anyhow::{Context, Result};

use crate::util::json::{obj, Json};

/// Ring capacity: enough to hold the events around any one incident
/// without growing with traffic.
const RING_CAP: usize = 512;

/// Event severity, ordered.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

impl Level {
    pub fn as_str(&self) -> &'static str {
        match self {
            Level::Debug => "debug",
            Level::Info => "info",
            Level::Warn => "warn",
            Level::Error => "error",
        }
    }

    fn from_u8(v: u8) -> Level {
        match v {
            0 => Level::Debug,
            1 => Level::Info,
            2 => Level::Warn,
            _ => Level::Error,
        }
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

impl FromStr for Level {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Level> {
        match s {
            "debug" => Ok(Level::Debug),
            "info" => Ok(Level::Info),
            "warn" => Ok(Level::Warn),
            "error" => Ok(Level::Error),
            other => anyhow::bail!("unknown log level {other:?} (debug|info|warn|error)"),
        }
    }
}

/// One recorded lifecycle event.
#[derive(Debug, Clone)]
pub struct Event {
    /// Monotonic per-process sequence number (total order even within
    /// one millisecond).
    pub seq: u64,
    /// Unix milliseconds at emit time.
    pub ts_ms: u64,
    pub level: Level,
    /// Stable machine-readable kind (`"config_poisoned"`,
    /// `"admission_shed"`, ...) — what dashboards key off.
    pub event: &'static str,
    /// Served config the event concerns, when there is one.
    pub config: Option<String>,
    /// Correlated trace id (16-hex), when the event happened inside a
    /// traced request.
    pub trace: Option<String>,
    /// Human-readable detail.
    pub msg: String,
}

impl Event {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("seq", self.seq.into()),
            ("ts_ms", self.ts_ms.into()),
            ("level", self.level.as_str().into()),
            ("event", self.event.into()),
            ("msg", self.msg.as_str().into()),
        ];
        if let Some(c) = &self.config {
            pairs.push(("config", c.as_str().into()));
        }
        if let Some(t) = &self.trace {
            pairs.push(("trace", t.as_str().into()));
        }
        obj(pairs)
    }
}

struct EventLog {
    level: AtomicU8,
    seq: AtomicU64,
    ring: Mutex<VecDeque<Event>>,
    sink: Mutex<Option<File>>,
}

static GLOBAL: EventLog = EventLog {
    level: AtomicU8::new(Level::Info as u8),
    seq: AtomicU64::new(0),
    ring: Mutex::new(VecDeque::new()),
    sink: Mutex::new(None),
};

/// Set the minimum recorded level (CLI `--log-level`).
pub fn set_level(level: Level) {
    GLOBAL.level.store(level as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    Level::from_u8(GLOBAL.level.load(Ordering::Relaxed))
}

/// Would an event at `level` be recorded?  One relaxed atomic load —
/// emit sites on hot-ish paths gate on this (or use [`emit_fmt`])
/// before formatting.
pub fn enabled(level: Level) -> bool {
    level >= self::level()
}

/// Attach a JSONL file sink (CLI `--log-file`): every recorded event
/// is appended as one JSON line, surviving the process.
pub fn set_sink(path: &Path) -> Result<()> {
    let f = File::options()
        .create(true)
        .append(true)
        .open(path)
        .with_context(|| format!("open log sink {path:?}"))?;
    *GLOBAL.sink.lock().unwrap() = Some(f);
    Ok(())
}

fn now_ms() -> u64 {
    SystemTime::now().duration_since(UNIX_EPOCH).map(|d| d.as_millis() as u64).unwrap_or(0)
}

/// Record one event (no-op below the current level).
pub fn emit(
    level: Level,
    event: &'static str,
    config: Option<&str>,
    trace: Option<&str>,
    msg: String,
) {
    if !enabled(level) {
        return;
    }
    let e = Event {
        seq: GLOBAL.seq.fetch_add(1, Ordering::Relaxed),
        ts_ms: now_ms(),
        level,
        event,
        config: config.map(str::to_string),
        trace: trace.map(str::to_string),
        msg,
    };
    if let Some(f) = GLOBAL.sink.lock().unwrap().as_mut() {
        // best-effort: a full disk must not take serving down
        let _ = writeln!(f, "{}", e.to_json());
    }
    let mut ring = GLOBAL.ring.lock().unwrap();
    if ring.len() == RING_CAP {
        ring.pop_front();
    }
    ring.push_back(e);
}

/// [`emit`] with lazy formatting: the closure runs only when the level
/// passes, so disabled emit sites cost one atomic load.
pub fn emit_fmt(level: Level, event: &'static str, msg: impl FnOnce() -> String) {
    if enabled(level) {
        emit(level, event, None, None, msg());
    }
}

/// [`emit_fmt`] tagged with the config it concerns.
pub fn emit_cfg(level: Level, event: &'static str, config: &str, msg: impl FnOnce() -> String) {
    if enabled(level) {
        emit(level, event, Some(config), None, msg());
    }
}

/// Newest-first slice of the ring: up to `n` events at `min_level` or
/// above, optionally only those correlated with `trace`.
pub fn recent(n: usize, min_level: Option<Level>, trace: Option<&str>) -> Vec<Event> {
    let ring = GLOBAL.ring.lock().unwrap();
    ring.iter()
        .rev()
        .filter(|e| min_level.is_none_or(|l| e.level >= l))
        .filter(|e| trace.is_none_or(|t| e.trace.as_deref() == Some(t)))
        .take(n)
        .cloned()
        .collect()
}

/// Total events recorded since process start (ring evictions included).
pub fn recorded() -> u64 {
    GLOBAL.seq.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: the log is process-global and tests run concurrently, so
    // assertions key on unique event kinds rather than global counts.

    #[test]
    fn emit_and_recall_by_kind_and_level() {
        emit(Level::Warn, "test_ev_alpha", Some("cfg_a"), None, "first".into());
        emit(Level::Error, "test_ev_alpha", Some("cfg_a"), None, "second".into());
        let evs = recent(RING_CAP, Some(Level::Warn), None);
        let mine: Vec<_> = evs.iter().filter(|e| e.event == "test_ev_alpha").collect();
        assert!(mine.len() >= 2);
        // newest first
        assert_eq!(mine[0].msg, "second");
        assert_eq!(mine[0].level, Level::Error);
        assert_eq!(mine[1].config.as_deref(), Some("cfg_a"));
        assert!(mine[0].seq > mine[1].seq);
    }

    #[test]
    fn trace_filter_correlates() {
        emit(Level::Info, "test_ev_traced", None, Some("00000000feedbeef"), "hit".into());
        emit(Level::Info, "test_ev_traced", None, Some("0000000000000001"), "miss".into());
        let evs = recent(RING_CAP, None, Some("00000000feedbeef"));
        assert!(evs.iter().any(|e| e.event == "test_ev_traced" && e.msg == "hit"));
        assert!(!evs.iter().any(|e| e.msg == "miss"));
    }

    #[test]
    fn debug_is_filtered_at_default_level() {
        // default level is Info: a Debug emit is dropped entirely
        emit(Level::Debug, "test_ev_debug_dropped", None, None, "gone".into());
        let evs = recent(RING_CAP, None, None);
        assert!(!evs.iter().any(|e| e.event == "test_ev_debug_dropped"));
        assert!(!enabled(Level::Debug));
        assert!(enabled(Level::Info));
    }

    #[test]
    fn event_json_shape() {
        let e = Event {
            seq: 7,
            ts_ms: 1234,
            level: Level::Warn,
            event: "config_poisoned",
            config: Some("iris_w4".into()),
            trace: Some("00000000deadbeef".into()),
            msg: "audit mismatch".into(),
        };
        let j = Json::parse(&e.to_json().to_string()).unwrap();
        assert_eq!(j.get("level").unwrap().as_str().unwrap(), "warn");
        assert_eq!(j.get("event").unwrap().as_str().unwrap(), "config_poisoned");
        assert_eq!(j.get("config").unwrap().as_str().unwrap(), "iris_w4");
        assert_eq!(j.get("trace").unwrap().as_str().unwrap(), "00000000deadbeef");
        assert_eq!(j.get("seq").unwrap().as_i64().unwrap(), 7);
    }

    #[test]
    fn level_round_trips_strings() {
        for l in [Level::Debug, Level::Info, Level::Warn, Level::Error] {
            assert_eq!(l.as_str().parse::<Level>().unwrap(), l);
        }
        assert!("loud".parse::<Level>().is_err());
    }
}
