//! Request spans: a trace id minted at ingress plus named per-stage
//! timings collected as the request crosses the layers.  A `Span` is
//! plain data — building one costs a handful of integer stores; all
//! locking lives in [`crate::obs::Obs`] and is paid only once per
//! request, at completion.

use std::fmt;

use anyhow::Result;

use crate::util::json::{obj, Json};

/// A 64-bit trace id, rendered as 16 lowercase hex digits on the wire
/// (`"trace"` field + `X-Trace-Id` header).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TraceId(pub u64);

impl TraceId {
    /// Mint an id from a seed + sequence pair (splitmix64 finalizer:
    /// distinct inputs give distinct ids, and ids from two nodes
    /// seeded differently do not collide in practice).
    pub fn mint(seed: u64, seq: u64) -> TraceId {
        let mut z = seed ^ seq.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        TraceId(z ^ (z >> 31))
    }

    pub fn to_hex(self) -> String {
        format!("{:016x}", self.0)
    }

    /// Parse the 16-hex-digit wire form (also accepts shorter hex).
    pub fn parse(s: &str) -> Option<TraceId> {
        if s.is_empty() || s.len() > 16 || !s.bytes().all(|b| b.is_ascii_hexdigit()) {
            return None;
        }
        u64::from_str_radix(s, 16).ok().map(TraceId)
    }
}

impl fmt::Display for TraceId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:016x}", self.0)
    }
}

/// The named stages a request can cross, in pipeline order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// enqueue → picked into a batch by the dispatcher
    QueueWait,
    /// picked up → batch flushed (waiting for batchmates / linger)
    BatchLinger,
    /// coordinator-side overhead around the engine call
    Dispatch,
    /// farm: job submitted → shard thread picks it up
    ShardWait,
    /// engine/shard execution proper (sim, fast path, or remote hop)
    Execute,
    /// farm: differential audit simulation on the fast path
    Audit,
    /// net: response JSON serialization + socket write
    Encode,
}

impl Stage {
    pub const ALL: [Stage; 7] = [
        Stage::QueueWait,
        Stage::BatchLinger,
        Stage::Dispatch,
        Stage::ShardWait,
        Stage::Execute,
        Stage::Audit,
        Stage::Encode,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Stage::QueueWait => "queue_wait",
            Stage::BatchLinger => "batch_linger",
            Stage::Dispatch => "dispatch",
            Stage::ShardWait => "shard_wait",
            Stage::Execute => "execute",
            Stage::Audit => "audit",
            Stage::Encode => "encode",
        }
    }

    pub fn parse(s: &str) -> Option<Stage> {
        Stage::ALL.into_iter().find(|st| st.name() == s)
    }

    fn index(self) -> usize {
        Stage::ALL.iter().position(|&s| s == self).unwrap()
    }
}

/// Per-stage µs timings for one request — a fixed-size value type, so
/// recording a stage is one store with no allocation or locking.
/// Unset stages stay `None` and are omitted from the wire form.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StageSet([Option<u64>; 7]);

impl StageSet {
    pub fn new() -> StageSet {
        StageSet::default()
    }

    pub fn set(&mut self, stage: Stage, us: u64) {
        self.0[stage.index()] = Some(us);
    }

    /// Accumulate into a stage (used when one request crosses the same
    /// stage twice, e.g. an audited fast-path answer).
    pub fn add(&mut self, stage: Stage, us: u64) {
        let slot = &mut self.0[stage.index()];
        *slot = Some(slot.unwrap_or(0) + us);
    }

    pub fn get(&self, stage: Stage) -> Option<u64> {
        self.0[stage.index()]
    }

    /// Recorded stages in pipeline order.
    pub fn iter(&self) -> impl Iterator<Item = (Stage, u64)> + '_ {
        Stage::ALL.into_iter().filter_map(|s| self.get(s).map(|us| (s, us)))
    }

    /// Sum of all recorded stage times.
    pub fn sum_us(&self) -> u64 {
        self.0.iter().flatten().sum()
    }

    pub fn is_empty(&self) -> bool {
        self.0.iter().all(|s| s.is_none())
    }
}

/// One request's trace: end-to-end timing, per-stage breakdown,
/// execution attribution, and (for fan-out requests) child spans from
/// the remote nodes that executed chunks of the batch.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    pub trace: TraceId,
    pub config: String,
    /// Which node produced this span ("" = the local node; the
    /// coordinator that fans out stamps each child with the node addr).
    pub node: String,
    pub total_us: u64,
    pub stages: StageSet,
    /// `ExecMode` name (`sim` / `fast` / `audited`) when the farm
    /// answered; `None` for engines without an execution mode.
    pub mode: Option<String>,
    pub cycles: Option<u64>,
    pub energy_mj: Option<f64>,
    pub err: Option<String>,
    pub children: Vec<Span>,
}

impl Span {
    pub fn new(trace: TraceId, config: impl Into<String>) -> Span {
        Span {
            trace,
            config: config.into(),
            node: String::new(),
            total_us: 0,
            stages: StageSet::new(),
            mode: None,
            cycles: None,
            energy_mj: None,
            err: None,
            children: Vec::new(),
        }
    }

    pub fn to_json(&self) -> Json {
        let stages = Json::Obj(
            self.stages
                .iter()
                .map(|(s, us)| (s.name().to_string(), Json::Num(us as f64)))
                .collect(),
        );
        let mut o = obj([
            ("trace", Json::Str(self.trace.to_hex())),
            ("config", Json::Str(self.config.clone())),
            ("total_us", Json::Num(self.total_us as f64)),
            ("stages", stages),
        ]);
        let Json::Obj(map) = &mut o else { unreachable!() };
        if !self.node.is_empty() {
            map.insert("node".to_string(), Json::Str(self.node.clone()));
        }
        if let Some(m) = &self.mode {
            map.insert("mode".to_string(), Json::Str(m.clone()));
        }
        if let Some(c) = self.cycles {
            map.insert("cycles".to_string(), Json::Num(c as f64));
        }
        if let Some(e) = self.energy_mj {
            map.insert("energy_mj".to_string(), Json::Num(e));
        }
        if let Some(e) = &self.err {
            map.insert("err".to_string(), Json::Str(e.clone()));
        }
        if !self.children.is_empty() {
            map.insert(
                "children".to_string(),
                Json::Arr(self.children.iter().map(|c| c.to_json()).collect()),
            );
        }
        o
    }

    /// Tolerant decode: unknown stage names and missing optional
    /// fields are skipped, so peers can grow the schema.
    pub fn from_json(v: &Json) -> Result<Span> {
        let trace = TraceId::parse(v.get("trace")?.as_str()?)
            .ok_or_else(|| anyhow::anyhow!("bad trace id in span"))?;
        let mut span = Span::new(trace, v.get("config")?.as_str()?);
        span.total_us = v.get("total_us")?.as_i64()?.max(0) as u64;
        if let Some(Json::Obj(stages)) = v.opt("stages") {
            for (name, val) in stages {
                if let (Some(stage), Ok(us)) = (Stage::parse(name), val.as_i64()) {
                    span.stages.set(stage, us.max(0) as u64);
                }
            }
        }
        if let Some(n) = v.opt("node") {
            span.node = n.as_str()?.to_string();
        }
        if let Some(m) = v.opt("mode") {
            span.mode = Some(m.as_str()?.to_string());
        }
        if let Some(c) = v.opt("cycles") {
            span.cycles = Some(c.as_i64()?.max(0) as u64);
        }
        if let Some(e) = v.opt("energy_mj") {
            span.energy_mj = Some(e.as_f64()?);
        }
        if let Some(e) = v.opt("err") {
            span.err = Some(e.as_str()?.to_string());
        }
        if let Some(kids) = v.opt("children") {
            for kid in kids.as_arr()? {
                span.children.push(Span::from_json(kid)?);
            }
        }
        Ok(span)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_ids_are_distinct_and_round_trip_hex() {
        let a = TraceId::mint(0xabc, 1);
        let b = TraceId::mint(0xabc, 2);
        let c = TraceId::mint(0xdef, 1);
        assert_ne!(a, b);
        assert_ne!(a, c);
        let hex = a.to_hex();
        assert_eq!(hex.len(), 16);
        assert_eq!(TraceId::parse(&hex), Some(a));
        assert_eq!(TraceId::parse("nope!"), None);
        assert_eq!(TraceId::parse(""), None);
        assert_eq!(TraceId::parse("123456789abcdef01"), None, "too long");
    }

    #[test]
    fn stage_set_records_and_sums() {
        let mut s = StageSet::new();
        assert!(s.is_empty());
        s.set(Stage::QueueWait, 10);
        s.set(Stage::Execute, 100);
        s.add(Stage::Execute, 5);
        assert_eq!(s.get(Stage::Execute), Some(105));
        assert_eq!(s.get(Stage::Audit), None);
        assert_eq!(s.sum_us(), 115);
        let order: Vec<&str> = s.iter().map(|(st, _)| st.name()).collect();
        assert_eq!(order, ["queue_wait", "execute"], "pipeline order");
    }

    #[test]
    fn span_json_round_trip_with_children() {
        let mut root = Span::new(TraceId::mint(7, 7), "cfg");
        root.total_us = 1234;
        root.stages.set(Stage::QueueWait, 20);
        root.stages.set(Stage::Execute, 1000);
        root.mode = Some("fast".to_string());
        root.cycles = Some(4321);
        root.energy_mj = Some(0.125);
        let mut kid = Span::new(root.trace, "cfg");
        kid.node = "127.0.0.1:9999".to_string();
        kid.total_us = 900;
        kid.stages.set(Stage::Execute, 880);
        kid.err = Some("scripted".to_string());
        root.children.push(kid);
        let back = Span::from_json(&root.to_json()).unwrap();
        assert_eq!(back, root);
    }

    #[test]
    fn span_decode_tolerates_unknown_stages_and_missing_fields() {
        let v = Json::parse(
            r#"{"trace":"00000000000000ff","config":"c","total_us":5,
                "stages":{"execute":3,"warp_drive":9}}"#,
        )
        .unwrap();
        let s = Span::from_json(&v).unwrap();
        assert_eq!(s.trace, TraceId(0xff));
        assert_eq!(s.stages.get(Stage::Execute), Some(3));
        assert_eq!(s.stages.sum_us(), 3, "unknown stage skipped");
        assert!(s.mode.is_none() && s.children.is_empty());
    }
}
