//! Span retention + stage-level telemetry: one [`Obs`] per serving
//! process.  The hot path pays a single short mutex acquisition per
//! *completed* request (`observe`), never per stage — stages
//! accumulate lock-free in the request's own [`StageSet`] and are
//! folded in here at the end.
//!
//! Retention policy (both always on):
//! * **1-in-N sampling** — every `sample_every`-th completed request
//!   keeps its full span tree, so the ring always holds a
//!   representative cross-section of traffic;
//! * **tail capture** — any request slower than the rolling p99 of
//!   the end-to-end latency keeps its span too, so the traces you
//!   actually need (the slow ones) are there when you look.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::coordinator::metrics::Histogram;

use super::slo::{self, SloSnapshot, SloTargets, SloTracker};
use super::span::{Span, Stage, StageSet, TraceId};

/// Observability knobs (fixed at server build time).
#[derive(Debug, Clone, Copy)]
pub struct ObsOpts {
    /// Keep every Nth request's span unconditionally (1 = keep all).
    pub sample_every: u64,
    /// Ring-buffer capacity for retained spans (oldest evicted first).
    pub ring_cap: usize,
    /// Serving-level objectives (`--slo p99=...,avail=...`); None
    /// disables SLO tracking entirely.
    pub slo: Option<SloTargets>,
}

impl Default for ObsOpts {
    fn default() -> Self {
        ObsOpts { sample_every: 64, ring_cap: 256, slo: None }
    }
}

/// The rolling-p99 tail threshold only activates once this many
/// requests have been observed (a p99 over a handful of samples is
/// noise and would retain everything).
const TAIL_MIN_COUNT: u64 = 32;
/// Refresh the cached tail threshold every this many observations
/// (computing a quantile per request would be wasted work).
const TAIL_REFRESH: u64 = 16;

/// Per-config stage histograms: one latency histogram per stage name.
#[derive(Debug, Clone, Default)]
pub struct StageMetrics {
    hists: [Option<Histogram>; 7],
}

impl StageMetrics {
    fn record(&mut self, stages: &StageSet) {
        for (stage, us) in stages.iter() {
            self.record_one(stage, us);
        }
    }

    fn record_one(&mut self, stage: Stage, us: u64) {
        let idx = Stage::ALL.iter().position(|&s| s == stage).unwrap();
        self.hists[idx].get_or_insert_with(Histogram::new).record_us(us);
    }

    /// Fold another snapshot's histograms into this one (fleet
    /// aggregation / cross-config rollups).
    pub fn merge(&mut self, other: &StageMetrics) {
        for (mine, theirs) in self.hists.iter_mut().zip(&other.hists) {
            match (mine.as_mut(), theirs) {
                (Some(m), Some(t)) => m.merge(t),
                (None, Some(t)) => *mine = Some(t.clone()),
                _ => {}
            }
        }
    }

    /// Stages that have received at least one sample, pipeline order.
    pub fn iter(&self) -> impl Iterator<Item = (Stage, &Histogram)> + '_ {
        Stage::ALL
            .into_iter()
            .zip(&self.hists)
            .filter_map(|(s, h)| h.as_ref().map(|h| (s, h)))
    }

    pub fn get(&self, stage: Stage) -> Option<&Histogram> {
        let idx = Stage::ALL.iter().position(|&s| s == stage).unwrap();
        self.hists[idx].as_ref()
    }
}

struct Inner {
    ring: VecDeque<Span>,
    /// Global end-to-end latency across configs (drives the rolling
    /// tail threshold).
    latency: Histogram,
    /// Cached p99-in-µs threshold; 0 = tail capture not active yet.
    tail_us: u64,
    stages: BTreeMap<String, StageMetrics>,
}

/// Process-wide observability hub: mints trace ids, decides span
/// retention, and aggregates per-config stage histograms.
pub struct Obs {
    opts: ObsOpts,
    seed: u64,
    seq: AtomicU64,
    observed: AtomicU64,
    inner: Mutex<Inner>,
    /// Process-relative clock anchoring the SLO one-second buckets.
    start: Instant,
    /// Per-config SLO good/total rings (empty unless `opts.slo`).
    slo_trackers: Mutex<BTreeMap<String, SloTracker>>,
}

impl Default for Obs {
    fn default() -> Self {
        Obs::new(ObsOpts::default())
    }
}

impl Obs {
    pub fn new(opts: ObsOpts) -> Obs {
        // seed trace-id minting so two nodes started the same
        // nanosecond still diverge by pid
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        let seed = nanos ^ ((std::process::id() as u64) << 32);
        Obs {
            opts: ObsOpts {
                sample_every: opts.sample_every.max(1),
                ring_cap: opts.ring_cap.max(1),
                slo: opts.slo,
            },
            seed,
            seq: AtomicU64::new(0),
            observed: AtomicU64::new(0),
            inner: Mutex::new(Inner {
                ring: VecDeque::new(),
                latency: Histogram::new(),
                tail_us: 0,
                stages: BTreeMap::new(),
            }),
            start: Instant::now(),
            slo_trackers: Mutex::new(BTreeMap::new()),
        }
    }

    pub fn opts(&self) -> ObsOpts {
        self.opts
    }

    /// Mint a fresh trace id (ingress: coordinator `submit` or the
    /// net front when the client did not send one).
    pub fn next_trace(&self) -> TraceId {
        TraceId::mint(self.seed, self.seq.fetch_add(1, Ordering::Relaxed))
    }

    /// Record a completed request's telemetry (stage + end-to-end
    /// histograms) and decide retention: `true` means the caller
    /// should build the full span and [`keep`](Obs::keep) it.
    pub fn observe(&self, config: &str, stages: &StageSet, total: Duration) -> bool {
        let n = self.observed.fetch_add(1, Ordering::Relaxed);
        let total_us = total.as_micros() as u64;
        let mut inner = self.inner.lock().unwrap();
        inner.latency.record_us(total_us);
        if !stages.is_empty() {
            inner.stages.entry(config.to_string()).or_default().record(stages);
        }
        if n % TAIL_REFRESH == 0 && inner.latency.count() >= TAIL_MIN_COUNT {
            inner.tail_us = inner.latency.quantile_us(0.99);
        }
        let tail_hit = inner.tail_us > 0 && total_us >= inner.tail_us;
        n % self.opts.sample_every == 0 || tail_hit
    }

    /// Record one stage sample outside the `observe` flow — for stages
    /// measured after the span is already sealed (the net front's
    /// `encode`: response serialization + socket write).
    pub fn record_stage(&self, config: &str, stage: Stage, us: u64) {
        let mut inner = self.inner.lock().unwrap();
        inner.stages.entry(config.to_string()).or_default().record_one(stage, us);
    }

    /// Retain a span in the ring buffer, evicting oldest-first.
    pub fn keep(&self, span: Span) {
        let mut inner = self.inner.lock().unwrap();
        if inner.ring.len() >= self.opts.ring_cap {
            inner.ring.pop_front();
        }
        inner.ring.push_back(span);
    }

    /// Look a retained span up by trace id (newest match wins).
    pub fn get(&self, trace: TraceId) -> Option<Span> {
        let inner = self.inner.lock().unwrap();
        inner.ring.iter().rev().find(|s| s.trace == trace).cloned()
    }

    /// The most recent `n` retained spans, newest first.
    pub fn recent(&self, n: usize) -> Vec<Span> {
        let inner = self.inner.lock().unwrap();
        inner.ring.iter().rev().take(n).cloned().collect()
    }

    pub fn retained(&self) -> usize {
        self.inner.lock().unwrap().ring.len()
    }

    /// Requests observed so far (sampled or not).
    pub fn observed(&self) -> u64 {
        self.observed.load(Ordering::Relaxed)
    }

    /// Snapshot of the per-config stage histograms.
    pub fn stage_snapshot(&self) -> BTreeMap<String, StageMetrics> {
        self.inner.lock().unwrap().stages.clone()
    }

    /// Fold a remote node's stage snapshot into ours (fleet view).
    pub fn merge_stages(&self, other: &BTreeMap<String, StageMetrics>) {
        let mut inner = self.inner.lock().unwrap();
        for (cfg, sm) in other {
            inner.stages.entry(cfg.clone()).or_default().merge(sm);
        }
    }

    /// Snapshot of the global end-to-end latency histogram.
    pub fn latency_snapshot(&self) -> Histogram {
        self.inner.lock().unwrap().latency.clone()
    }

    /// Score one completed (or shed/failed) request against the SLO
    /// targets.  No-op unless targets are configured.
    pub fn slo_record(&self, config: &str, ok: bool, latency: Duration) {
        let Some(targets) = self.opts.slo else { return };
        let now_s = self.start.elapsed().as_secs();
        let good = targets.good(ok, latency);
        self.slo_trackers
            .lock()
            .unwrap()
            .entry(config.to_string())
            .or_default()
            .record(now_s, good);
    }

    /// Evaluate every tracked config against the SLO targets right
    /// now.  `None` when SLO tracking is disabled.
    pub fn slo_snapshot(&self) -> Option<SloSnapshot> {
        let targets = self.opts.slo?;
        let now_s = self.start.elapsed().as_secs();
        let trackers = self.slo_trackers.lock().unwrap();
        Some(SloSnapshot {
            targets,
            configs: trackers
                .iter()
                .map(|(cfg, tr)| slo::evaluate(cfg, tr, &targets, now_s))
                .collect(),
        })
    }
}

/// Merge two per-config stage snapshots (used by `report::serving`
/// when combining local + fleet views).
pub fn merge_stage_maps(
    into: &mut BTreeMap<String, StageMetrics>,
    other: &BTreeMap<String, StageMetrics>,
) {
    for (cfg, sm) in other {
        into.entry(cfg.clone()).or_default().merge(sm);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(trace: TraceId) -> Span {
        Span::new(trace, "cfg")
    }

    #[test]
    fn one_in_n_sampling_is_always_on() {
        let obs = Obs::new(ObsOpts { sample_every: 4, ring_cap: 8, slo: None });
        let stages = StageSet::new();
        let kept: Vec<bool> =
            (0..8).map(|_| obs.observe("c", &stages, Duration::from_micros(10))).collect();
        assert_eq!(kept, [true, false, false, false, true, false, false, false]);
        assert_eq!(obs.observed(), 8);
    }

    #[test]
    fn ring_evicts_oldest_first() {
        let obs = Obs::new(ObsOpts { sample_every: 1, ring_cap: 3, slo: None });
        let ids: Vec<TraceId> = (0..5).map(|_| obs.next_trace()).collect();
        for &id in &ids {
            obs.keep(span(id));
        }
        assert_eq!(obs.retained(), 3);
        assert!(obs.get(ids[0]).is_none(), "oldest evicted");
        assert!(obs.get(ids[1]).is_none(), "second-oldest evicted");
        for &id in &ids[2..] {
            assert!(obs.get(id).is_some(), "newest three retained");
        }
        let recent = obs.recent(2);
        assert_eq!(recent[0].trace, ids[4], "newest first");
        assert_eq!(recent[1].trace, ids[3]);
    }

    #[test]
    fn tail_capture_retains_a_slow_request() {
        // sampling alone would keep only request 0; the slow request
        // must be retained by the rolling-p99 tail rule instead
        let obs = Obs::new(ObsOpts { sample_every: 1_000_000, ring_cap: 8, slo: None });
        let stages = StageSet::new();
        let mut kept_fast = 0;
        for _ in 0..64 {
            if obs.observe("c", &stages, Duration::from_micros(100)) {
                kept_fast += 1;
            }
        }
        assert!(kept_fast <= 1, "only the 1-in-N sample survives: {kept_fast}");
        let slow = obs.observe("c", &stages, Duration::from_millis(500));
        assert!(slow, "a request slower than the rolling p99 is retained");
    }

    #[test]
    fn stage_histograms_aggregate_per_config() {
        let obs = Obs::new(ObsOpts::default());
        let mut s = StageSet::new();
        s.set(Stage::QueueWait, 10);
        s.set(Stage::Execute, 300);
        obs.observe("a", &s, Duration::from_micros(350));
        obs.observe("a", &s, Duration::from_micros(350));
        obs.observe("b", &s, Duration::from_micros(350));
        let snap = obs.stage_snapshot();
        assert_eq!(snap.len(), 2);
        let a = &snap["a"];
        assert_eq!(a.get(Stage::Execute).unwrap().count(), 2);
        assert_eq!(a.get(Stage::QueueWait).unwrap().count(), 2);
        assert!(a.get(Stage::Audit).is_none(), "unrecorded stages stay absent");
        let names: Vec<&str> = a.iter().map(|(st, _)| st.name()).collect();
        assert_eq!(names, ["queue_wait", "execute"]);
    }

    #[test]
    fn slo_tracking_scores_requests_and_reports() {
        let opts = ObsOpts { slo: Some("p99=10ms,avail=50".parse().unwrap()), ..Default::default() };
        let obs = Obs::new(opts);
        // within objective, slow, failed
        obs.slo_record("a", true, Duration::from_millis(1));
        obs.slo_record("a", true, Duration::from_millis(100));
        obs.slo_record("a", false, Duration::from_millis(1));
        let snap = obs.slo_snapshot().expect("slo configured");
        assert_eq!(snap.configs.len(), 1);
        let c = &snap.configs[0];
        assert_eq!(c.config, "a");
        assert_eq!(c.short, (1, 3));
        // err 2/3 over a 50% budget: burning but within one test second
        assert!(c.burn_short > 1.0);

        let off = Obs::new(ObsOpts::default());
        off.slo_record("a", true, Duration::from_millis(1));
        assert!(off.slo_snapshot().is_none(), "no targets, no tracking");
    }

    #[test]
    fn stage_merge_folds_fleet_counts() {
        let obs = Obs::new(ObsOpts::default());
        let mut s = StageSet::new();
        s.set(Stage::Execute, 100);
        obs.observe("a", &s, Duration::from_micros(100));
        let remote = obs.stage_snapshot();
        obs.merge_stages(&remote);
        let snap = obs.stage_snapshot();
        assert_eq!(snap["a"].get(Stage::Execute).unwrap().count(), 2);
    }
}
