//! Framework extensibility demo (paper contribution 1, §III-C): attach
//! *user-defined* co-processors to SERV alongside the SVM accelerator.
//!
//! The paper: "since SERV only uses funct7 values 0x00 and 0x20
//! internally, other non-conflicting values (e.g., funct7 = 2, 3, etc.)
//! could be assigned to additional custom accelerators, each supporting
//! up to 8 operations via funct3."
//!
//! Here: funct7=1 SVM accel, funct7=2 mac32, funct7=3 popcount, and a
//! brand-new user CFU (funct7=4, saturating add) defined right in this
//! example — no framework changes needed, exactly the claim.
//!
//!     cargo run --release --example custom_cfu

use anyhow::Result;

use flexsvm::accel::mac::{MacAccel, OP_CLEAR, OP_MAC, OP_READ};
use flexsvm::accel::popcount::{PopcountAccel, OP_XNOR_POPCNT};
use flexsvm::accel::svm::SvmAccel;
use flexsvm::accel::{Cfu, CfuOutput};
use flexsvm::isa::reg::*;
use flexsvm::isa::Asm;
use flexsvm::serv::TimingConfig;
use flexsvm::soc::Soc;

/// A user-defined CFU: 32-bit saturating add (op 0).
struct SatAdd;

impl Cfu for SatAdd {
    fn name(&self) -> &'static str {
        "sat-add"
    }
    fn reset(&mut self) {}
    fn execute(&mut self, funct3: u8, rs1: u32, rs2: u32) -> Result<CfuOutput> {
        anyhow::ensure!(funct3 == 0, "sat-add has a single operation");
        let v = (rs1 as i32).saturating_add(rs2 as i32) as u32;
        Ok(CfuOutput { value: v, compute_cycles: 1 })
    }
    fn nand2_equivalents(&self) -> u64 {
        32 * 10
    }
}

fn main() -> Result<()> {
    // a program exercising all four CFUs
    let mut a = Asm::new(0);
    // mac32 (funct7=2): acc = 123*4 + 7*(-2) = 492 - 14 = 478
    a.cfu(2, OP_CLEAR, ZERO, ZERO, ZERO);
    a.li(A1, 123);
    a.li(A2, 4);
    a.cfu(2, OP_MAC, ZERO, A1, A2);
    a.li(A1, 7);
    a.li(A2, -2);
    a.cfu(2, OP_MAC, ZERO, A1, A2);
    a.cfu(2, OP_READ, S0, ZERO, ZERO);
    // popcount (funct7=3): xnor-popcount of equal words = 32
    a.li(A1, 0x1234_5678);
    a.cfu(3, OP_XNOR_POPCNT, S1, A1, A1);
    // user CFU (funct7=4): saturating add at the positive rail
    a.li(A1, i32::MAX);
    a.li(A2, 100);
    a.cfu(4, 0, S2, A1, A2);
    // svm accel (funct7=1): one calc4+res4 pass: 5*3 = 15, id 0
    a.cfu(1, 7, ZERO, ZERO, ZERO); // create_env
    a.li(A1, 5);
    a.li(A2, 3);
    a.cfu(1, 0, ZERO, A1, A2); // sv.calc4
    a.cfu(1, 1, S3, ZERO, ZERO); // sv.res4
    // results: a0 = mac, a1 = popcount + satadd check
    a.mv(A0, S0);
    a.mv(A1, S1);
    a.ecall();

    let mut soc = Soc::new(&a.assemble_bytes()?, TimingConfig::flexic());
    soc.register_cfu(1, Box::new(SvmAccel::new()))?;
    soc.register_cfu(2, Box::new(MacAccel::new()))?;
    soc.register_cfu(3, Box::new(PopcountAccel::new()))?;
    soc.register_cfu(4, Box::new(SatAdd))?;
    println!("registered CFUs: {:?}", soc.cfus.registered());

    let r = soc.run(10_000_000)?;
    let (a0, a1) = match r.exit {
        flexsvm::serv::Exit::Ecall { a0, a1 } => (a0, a1),
        e => anyhow::bail!("unexpected exit {e:?}"),
    };
    assert_eq!(a0 as i32, 478, "mac32");
    assert_eq!(a1, 32, "xnor-popcount");
    assert_eq!(soc.core.regs[S2 as usize] as i32, i32::MAX, "sat-add clamped");
    assert_eq!(soc.core.regs[S3 as usize] & 0xff, 0, "svm max_id");
    println!(
        "all 4 CFUs executed correctly in {} cycles ({} instructions)",
        r.stats.total(),
        r.stats.instret
    );
    println!("custom_cfu OK");
    Ok(())
}
