//! Quickstart: load an AOT-compiled SVM artifact, classify a few Iris
//! samples through the PJRT runtime, and cross-check against the
//! cycle-accurate SERV + accelerator simulation.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;

use flexsvm::power::FlexicModel;
use flexsvm::program::run::ProgramRunner;
use flexsvm::program::ProgramOpts;
use flexsvm::runtime::Engine;
use flexsvm::serv::TimingConfig;
use flexsvm::svm::model::artifacts_root;
use flexsvm::svm::Manifest;

fn main() -> Result<()> {
    // 1. artifacts: the build-time Python path (jax + pallas) has already
    //    trained, quantized and AOT-lowered every model — just load.
    let manifest = Manifest::load(&artifacts_root())?;
    let key = "iris_ovr_w4";
    let entry = manifest.config(key)?;
    println!(
        "{key}: {} classes x {} features, {}-bit weights, build-time accuracy {:.1}%",
        entry.n_classes,
        entry.n_features,
        entry.bits,
        entry.accuracy * 100.0
    );

    // 2. functional fast path: compiled HLO on the PJRT CPU client
    let mut engine = Engine::new()?;
    engine.load(&manifest, entry, 1)?;
    let test = manifest.test_set(&entry.dataset)?;
    let preds = engine.predict(key, 1, &test.x_q[..5])?;
    println!("PJRT predictions for 5 test samples: {preds:?} (labels {:?})", &test.y[..5]);

    // 3. the same inference on the cycle-accurate Bendable RISC-V SoC
    let model = manifest.model(entry)?;
    let power = FlexicModel::paper();
    let mut accel =
        ProgramRunner::accelerated(&model, TimingConfig::flexic(), ProgramOpts::default())?;
    let mut base = ProgramRunner::baseline(&model, TimingConfig::flexic())?;
    for (i, x) in test.x_q.iter().take(5).enumerate() {
        let (pa, sa) = accel.run_sample(x)?;
        let (pb, sb) = base.run_sample(x)?;
        assert_eq!(pa, preds[i], "SoC and PJRT must agree");
        assert_eq!(pa, pb, "accelerated and baseline programs must agree");
        println!(
            "sample {i}: class {pa} | SERV+accel {:>7} cyc ({:.1} ms, {:.3} mJ) | SERV-only {:>8} cyc ({:.0} ms) | {:>4.1}x",
            sa.total(),
            1e3 * power.latency_s(sa.total() as f64),
            power.energy_mj(sa.total() as f64),
            sb.total(),
            1e3 * power.latency_s(sb.total() as f64),
            sb.total() as f64 / sa.total() as f64,
        );
    }
    println!("quickstart OK");
    Ok(())
}
