//! Cycle-accurate simulation walkthrough: renders the Fig. 2 life-cycle
//! of ML-accelerator instructions (init → 32-cycle operand transmission
//! → accel_valid → compute → accel_ready → write-back) and the cycle
//! attribution of a full inference.
//!
//!     make artifacts && cargo run --release --example cycle_sim [config]

use anyhow::Result;

use flexsvm::program::run::ProgramRunner;
use flexsvm::program::ProgramOpts;
use flexsvm::serv::TimingConfig;
use flexsvm::soc::format_trace_line;
use flexsvm::svm::model::artifacts_root;
use flexsvm::svm::Manifest;

fn main() -> Result<()> {
    let key = std::env::args().nth(1).unwrap_or_else(|| "iris_ovr_w4".to_string());
    let manifest = Manifest::load(&artifacts_root())?;
    let entry = manifest.config(&key)?;
    let model = manifest.model(entry)?;
    let test = manifest.test_set(&entry.dataset)?;
    let timing = TimingConfig::flexic();

    println!("=== {key}: one inference on the Bendable RISC-V SoC ===\n");
    let mut runner = ProgramRunner::accelerated(&model, timing, ProgramOpts::default())?;
    runner.soc_mut().rearm();
    runner.poke_features(&test.x_q[0])?;

    let mut cfu_lines = 0usize;
    let mut other = 0usize;
    let mut cb = |info: &flexsvm::serv::StepInfo| {
        // show every accelerator instruction (the Fig. 2 handshake) and
        // the first few regular instructions for context
        if info.cfu.is_some() && cfu_lines < 12 {
            println!("{}", format_trace_line(info, &timing));
            cfu_lines += 1;
        } else if info.cfu.is_none() && other < 8 {
            println!("{}", format_trace_line(info, &timing));
            other += 1;
        }
    };
    let r = runner.soc_mut().run_traced(1_000_000_000, Some(&mut cb))?;

    println!("\npredicted class: {}", r.value());
    let s = r.stats;
    println!("cycle attribution over {} instructions:", s.instret);
    println!("  fetch    {:>8} cyc ({:>4.1}%)", s.fetch, 100.0 * s.fetch as f64 / s.total() as f64);
    println!("  exec     {:>8} cyc ({:>4.1}%)", s.exec, 100.0 * s.exec as f64 / s.total() as f64);
    println!("  data mem {:>8} cyc ({:>4.1}%)  [{} loads, {} stores]", s.data_mem, 100.0 * s.data_mem_share(), s.loads, s.stores);
    println!("  cfu      {:>8} cyc ({:>4.1}%)  [{} accelerator ops]", s.cfu, 100.0 * s.cfu as f64 / s.total() as f64, s.cfu_ops);
    println!("  total    {:>8} cyc = {:.1} ms at 52 kHz", s.total(), s.total() as f64 / 52.0);
    println!("\ncycle_sim OK");
    Ok(())
}
