//! END-TO-END DRIVER (DESIGN.md §4 experiment E2E): the full three-layer
//! stack on a real workload.
//!
//!   Layer 1/2 (build time): Pallas PE kernel + JAX model, AOT-lowered
//!     to HLO text by `make artifacts`.
//!   Layer 3 (this binary):  the Rust coordinator loads the compiled
//!     graphs on the PJRT CPU client and serves batched classification
//!     requests — routing per config, dynamic batching, backpressure —
//!     with Python nowhere on the request path.
//!
//! The workload streams the real held-out test vectors of four
//! Table-I configurations from 8 client threads, checks every answer
//! against the labels (accuracy must equal the build-time metric) and
//! reports throughput, latency percentiles and batch-formation stats.
//! The numbers land in EXPERIMENTS.md §E2E.
//!
//!     make artifacts && cargo run --release --example serve_inference
//!     (options: serve_inference <n_requests> <backend pjrt|native>)

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use anyhow::Result;

use flexsvm::coordinator::{Backend, Server, ServerOpts};
use flexsvm::svm::model::artifacts_root;
use flexsvm::svm::Manifest;

const WORKERS: usize = 8;

fn main() -> Result<()> {
    let n_requests: usize =
        std::env::args().nth(1).map(|s| s.parse()).transpose()?.unwrap_or(20_000);
    let backend = match std::env::args().nth(2).as_deref() {
        Some("native") => Backend::Native,
        _ => Backend::Pjrt,
    };
    let keys: Vec<String> = ["iris_ovr_w4", "bs_ovo_w8", "seeds_ovo_w4", "derm_ovr_w16"]
        .iter()
        .map(|s| s.to_string())
        .collect();

    let manifest = Manifest::load(&artifacts_root())?;
    let mut testsets = Vec::new();
    for k in &keys {
        let entry = manifest.config(k)?;
        testsets.push((k.clone(), manifest.test_set(&entry.dataset)?, entry.accuracy));
    }

    println!("starting coordinator ({backend:?}) serving {} configs ...", keys.len());
    let t_load = Instant::now();
    let server = Server::start(
        artifacts_root(),
        keys.clone(),
        ServerOpts {
            backend,
            batch_max: 64,
            compiled_batch: 64,
            linger: Duration::from_micros(500),
            queue_cap: 4096,
            eager_flush: true,
        },
    )?;
    println!("  all graphs compiled + resident in {:.2}s", t_load.elapsed().as_secs_f64());

    let client = server.client();
    let correct = AtomicU64::new(0);
    let done = AtomicU64::new(0);
    let t0 = Instant::now();
    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::new();
        for w in 0..WORKERS {
            let client = client.clone();
            let testsets = &testsets;
            let correct = &correct;
            let done = &done;
            handles.push(scope.spawn(move || -> Result<()> {
                for i in 0..n_requests / WORKERS {
                    let (key, test, _) = &testsets[(w + i) % testsets.len()];
                    let idx = (w * 7919 + i * 31) % test.len();
                    let resp = client.infer(key, &test.x_q[idx])?;
                    if resp.pred == test.y[idx] {
                        correct.fetch_add(1, Ordering::Relaxed);
                    }
                    done.fetch_add(1, Ordering::Relaxed);
                }
                Ok(())
            }));
        }
        for h in handles {
            h.join().unwrap()?;
        }
        Ok(())
    })?;
    let dt = t0.elapsed();
    let served = done.load(Ordering::Relaxed);
    let acc = correct.load(Ordering::Relaxed) as f64 / served as f64;

    println!("\n=== E2E results ===");
    println!(
        "served {served} requests from {WORKERS} clients in {:.2}s  ->  {:.0} req/s",
        dt.as_secs_f64(),
        served as f64 / dt.as_secs_f64()
    );
    println!("online accuracy over the mixed stream: {:.1}%", acc * 100.0);

    let mut metrics: Vec<_> = client.metrics()?.into_iter().collect();
    metrics.sort_by(|a, b| a.0.cmp(&b.0));
    for (key, m) in metrics {
        let h = m.latency.as_ref().unwrap();
        println!(
            "  {key:<16} {:>6} reqs | {:>5} batches (mean {:>4.1}/batch) | latency p50 {:>5} us  p99 {:>6} us  max {:>6} us",
            m.requests,
            m.batches,
            m.mean_batch(),
            h.quantile_us(0.50),
            h.quantile_us(0.99),
            h.max_us()
        );
    }

    // sanity: the mixed-stream accuracy must be the weighted mean of the
    // per-config build-time accuracies (same vectors, same models)
    let expect: f64 = testsets.iter().map(|(_, _, a)| a).sum::<f64>() / testsets.len() as f64;
    anyhow::ensure!(
        (acc - expect).abs() < 0.05,
        "online accuracy {acc:.3} diverges from expected {expect:.3}"
    );
    println!("serve_inference OK");
    Ok(())
}
