//! END-TO-END DRIVER (DESIGN.md §4 experiment E2E): the full stack on
//! a real workload.
//!
//!   Layer 1/2 (build time): Pallas PE kernel + JAX model, AOT-lowered
//!     to HLO text by `make artifacts`.
//!   Layer 3 (this binary):  the Rust coordinator serves batched
//!     classification requests — routing per config, dynamic batching,
//!     backpressure — with Python nowhere on the request path, over
//!     one of the three in-tree `Engine` implementations (any other
//!     backend plugs in through `Server::builder().engine(..)`):
//!
//!       pjrt    compiled HLO on the PJRT CPU client (`--features pjrt`)
//!       native  pure-Rust integer inference
//!       accel   the cycle-level SoC farm (SERV + SVM CFU shards) with
//!               per-request energy accounting — Table I under load
//!
//! The workload streams the real held-out test vectors of four
//! Table-I configurations from 8 client threads, checks every answer
//! against the native integer spec and the labels, and reports
//! throughput, latency percentiles and batch-formation stats; the
//! accel backend additionally prints the serving energy report
//! (energy/request, simulated cycles, accel-vs-baseline ratio).
//! The numbers land in EXPERIMENTS.md §E2E.
//!
//! With `--listen` the same drive runs over the wire instead: the
//! server goes behind `net::server` on a loopback socket and every
//! request is a real HTTP `POST /v1/infer` — re-checking
//! `native_mismatch == 0` across the process boundary (the §6
//! contract extended over the wire).
//!
//!     make artifacts && cargo run --release --example serve_inference
//!     (options: serve_inference [n_requests] [pjrt|native|accel] [--listen])

use std::collections::HashMap;
use std::time::{Duration, Instant};

use anyhow::Result;

use flexsvm::coordinator::{Backend, Client, Server};
use flexsvm::farm::{resolve_shards, FarmOpts};
use flexsvm::net::{drive_http, NetOpts, NetServer};
use flexsvm::power::FlexicModel;
use flexsvm::report::serving;
use flexsvm::svm::model::artifacts_root;
use flexsvm::svm::{Manifest, QuantModel};
use flexsvm::util::benchkit::{drive_clients, load_testsets};

const WORKERS: usize = 8;

/// Shared shape of the wire and in-process drive results.
struct Outcome {
    served: u64,
    label_correct: u64,
    native_mismatch: u64,
    shed: u64,
    wall: Duration,
    /// per-config (label-correct, answered) — the in-process drive
    /// tracks it; the wire drive doesn't (labels stay client-side)
    per_config: Option<HashMap<String, (u64, u64)>>,
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let listen = args.iter().any(|a| a == "--listen");
    let pos: Vec<&String> = args.iter().filter(|a| !a.starts_with("--")).collect();
    let n_requests: usize = pos.first().map(|s| s.parse()).transpose()?.unwrap_or(20_000);
    // default follows the build: pjrt when compiled in, else native
    let backend: Backend = match pos.get(1) {
        Some(s) => s.parse()?,
        None => Backend::default_for_build(),
    };
    let keys: Vec<String> = ["iris_ovr_w4", "bs_ovo_w8", "seeds_ovo_w4", "derm_ovr_w16"]
        .iter()
        .map(|s| s.to_string())
        .collect();

    let manifest = Manifest::load(&artifacts_root())?;
    let testsets = load_testsets(&manifest, &keys)?;
    let accuracies: Vec<f64> =
        keys.iter().map(|k| manifest.config(k).map(|e| e.accuracy)).collect::<Result<_>>()?;
    // native reference models: every served answer is checked against
    // the integer spec (differential serving check, all backends)
    let mut ref_models: HashMap<String, QuantModel> = HashMap::new();
    for k in &keys {
        ref_models.insert(k.clone(), manifest.model(manifest.config(k)?)?);
    }

    let farm_opts = FarmOpts::default();
    println!("starting coordinator ({backend}) serving {} configs ...", keys.len());
    if backend == Backend::Accel {
        println!(
            "  farm: {} SoC shards, warm program load + baseline calibration (one software-only\n  \
             inference per config — the slow part of startup on large models)",
            resolve_shards(farm_opts.shards)
        );
    }
    let t_load = Instant::now();
    let server = Server::builder()
        .artifacts(artifacts_root(), keys.clone())
        .backend(backend)
        .batch_max(64)
        .compiled_batch(64)
        .linger(Duration::from_micros(500))
        .queue_cap(4096)
        .farm(farm_opts)
        .start()?;
    println!("  backend resident in {:.2}s", t_load.elapsed().as_secs_f64());

    // drive either in-process or over a loopback socket; both paths
    // cross-check every answer against the native integer spec
    let (r, client, net, server): (Outcome, Client, Option<NetServer>, Option<Server>) = if listen
    {
        let net = NetServer::bind(server, "127.0.0.1:0", NetOpts::default())?;
        println!("  wire path: serving over http://{}", net.addr());
        let client = net.client();
        let d = drive_http(&net.addr().to_string(), &testsets, n_requests, WORKERS, Some(&ref_models))?;
        let r = Outcome {
            served: d.served,
            label_correct: d.label_correct,
            native_mismatch: d.native_mismatch,
            shed: d.shed,
            wall: d.wall,
            per_config: None,
        };
        (r, client, Some(net), None)
    } else {
        let client = server.client();
        let d = drive_clients(&client, &testsets, n_requests, WORKERS, Some(&ref_models))?;
        let r = Outcome {
            served: d.served,
            label_correct: d.label_correct,
            native_mismatch: d.native_mismatch,
            shed: 0,
            wall: d.wall,
            per_config: Some(d.per_config),
        };
        (r, client, None, Some(server))
    };
    let acc = r.label_correct as f64 / r.served as f64;

    println!("\n=== E2E results ===");
    println!(
        "served {} requests from {WORKERS} clients in {:.2}s  ->  {:.0} req/s{}",
        r.served,
        r.wall.as_secs_f64(),
        r.served as f64 / r.wall.as_secs_f64(),
        if listen { " (over loopback HTTP)" } else { "" }
    );
    if r.shed > 0 {
        println!("({} requests shed with 503 by admission control)", r.shed);
    }
    println!("online accuracy over the mixed stream: {:.1}%", acc * 100.0);
    anyhow::ensure!(
        r.native_mismatch == 0,
        "{} answers diverged from the native integer spec",
        r.native_mismatch
    );
    println!("every prediction matches the native backend (0 mismatches)");

    let metrics = client.metrics()?;
    let mut sorted: Vec<_> = metrics.iter().collect();
    sorted.sort_by(|a, b| a.0.cmp(b.0));
    for (key, m) in &sorted {
        let h = m.latency.as_ref().unwrap();
        println!(
            "  {key:<16} {:>6} reqs | {:>5} batches (mean {:>4.1}/batch) | latency p50 {:>5} us  p99 {:>6} us  max {:>6} us",
            m.requests,
            m.batches,
            m.mean_batch(),
            h.quantile_us(0.50),
            h.quantile_us(0.99),
            h.max_us()
        );
    }

    if backend == Backend::Accel {
        let farm = client.engine_metrics()?.farm;
        let stages = client.obs().stage_snapshot();
        let nm = net.as_ref().map(|n| n.metrics());
        print!(
            "{}",
            serving::render(
                &metrics,
                r.wall,
                farm.as_ref(),
                &FlexicModel::paper(),
                Some(&stages),
                None,
                r.per_config.as_ref(),
                nm.as_ref(),
                None,
            )
        );
        // Table-I sanity: at least one served config's accel-vs-baseline
        // cycle ratio must sit inside the paper's reported speedup band
        // (Table I spans 1.5x..48.6x across configs).
        let ratios: Vec<(String, f64)> = sorted
            .iter()
            .map(|(k, m)| ((*k).clone(), m.accel_speedup()))
            .filter(|(_, r)| *r > 0.0)
            .collect();
        anyhow::ensure!(
            ratios.iter().any(|(_, r)| (1.5..=60.0).contains(r)),
            "no config's accel-vs-baseline ratio {ratios:?} is in the paper's range"
        );
        println!("accel-vs-baseline ratios {ratios:?} — consistent with Table I");
    }

    // sanity: the mixed-stream accuracy must be the weighted mean of the
    // per-config build-time accuracies (same vectors, same models)
    let expect: f64 = accuracies.iter().sum::<f64>() / accuracies.len() as f64;
    anyhow::ensure!(
        (acc - expect).abs() < 0.05,
        "online accuracy {acc:.3} diverges from expected {expect:.3}"
    );
    match net {
        Some(n) => n.shutdown()?,
        None => server.expect("in-process mode keeps the server").shutdown()?,
    }
    println!("serve_inference OK");
    Ok(())
}
